"""Cross-shard partitioned GraphStore.

Each worker owns one contiguous node-range partition of EVERY node set
(`ShardMap`: shard s of S owns ``[s*n//S, (s+1)*n//S)``).  All shards
open the same `GraphDirectory` mmap, so "owning" a range costs nothing —
it only decides which shard ANSWERS a lookup, which is what keeps each
worker's resident set bounded by the pages its partition actually
touches while the fleet as a whole covers the graph.

Lookups for nodes outside the local range batch into one `NBR` / `FEAT`
request frame per owning peer over the `sampling_service` wire protocol
(`GraphShardServer` answers them from its own mmap), with a per-worker
remote-neighbor LRU so frontier-heavy hops don't storm the network.

Determinism: every shard serves slices of the SAME CSR files, so a
neighbor list is byte-identical whether it came from the local mmap, a
peer, the LRU, or the local fallback after a peer died — which is why
`ShardedGraphStore` keeps the `(plan, seeds, base_seed, epoch, step)`
bit-identical sampling contract at any shard count, including across a
kill-one-shard-worker rebalance.
"""
from __future__ import annotations

import socket
import threading
import time
from collections import OrderedDict
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.data.sampling import GraphStore
from repro.sampling_service import wire
from repro.sampling_service.transport import Address, TcpTransport
from repro.storage.format import MmapGraphStore


def shard_bounds(n: int, num_shards: int) -> np.ndarray:
    """Partition boundaries: shard s owns ``[bounds[s], bounds[s+1])``."""
    return (np.arange(num_shards + 1, dtype=np.int64) * n) // num_shards


class ShardMap:
    """Pure node-id -> owning-shard arithmetic for every node set."""

    def __init__(self, num_nodes: Mapping[str, int], num_shards: int):
        self.num_shards = num_shards
        self.bounds = {ns: shard_bounds(n, num_shards)
                       for ns, n in num_nodes.items()}

    def owner(self, node_set: str, nodes: np.ndarray) -> np.ndarray:
        b = self.bounds[node_set]
        return np.searchsorted(b, np.asarray(nodes, np.int64),
                               side="right") - 1

    def node_range(self, node_set: str, shard: int) -> tuple[int, int]:
        b = self.bounds[node_set]
        return int(b[shard]), int(b[shard + 1])


class GraphShardServer:
    """Serve batched NBR/FEAT lookups from a local store over TCP.

    One accept thread polls the listener; each connection gets its own
    handler thread.  All threads are daemons AND joined in `close()`
    (repro-lint THR001/THR002), and every receiving socket runs under a
    timeout (SOC001)."""

    def __init__(self, store, *, host: str = "127.0.0.1",
                 poll_interval: float = 0.25,
                 frame_timeout: float = 30.0):
        self.store = store
        self.poll_interval = poll_interval
        self.frame_timeout = frame_timeout
        self._lsock = TcpTransport(host).listen()
        self._lsock.settimeout(poll_interval)
        self.address: Address = self._lsock.getsockname()[:2]
        self._closed = threading.Event()
        self._lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._conns: list[socket.socket] = []
        self.requests_served = 0
        accept = threading.Thread(target=self._accept_loop,
                                  name="graph-shard-accept", daemon=True)
        accept.start()
        self._threads.append(accept)

    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _ = self._lsock.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed under us
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="graph-shard-conn", daemon=True)
            with self._lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        try:
            while not self._closed.is_set():
                try:
                    kind, meta, payload = wire.recv_frame(
                        conn, timeout=self.poll_interval,
                        frame_timeout=self.frame_timeout)
                except socket.timeout:
                    continue
                except (EOFError, OSError, wire.WireError):
                    return
                try:
                    self._answer(conn, kind, meta, payload)
                except OSError:
                    return  # peer went away mid-reply
        finally:
            conn.close()

    def _answer(self, conn: socket.socket, kind: str, meta: dict,
                payload) -> None:
        if kind == wire.NBR:
            nodes = np.asarray(payload["nodes"], np.int64)
            nbrs = self.store.neighbors_batch(meta["edge_set"], nodes)
            counts = np.asarray([len(x) for x in nbrs], np.int64)
            flat = (np.concatenate(nbrs).astype(np.int64, copy=False)
                    if nbrs else np.zeros(0, np.int64))
            reply = (wire.NBRS, {"counts": counts, "neighbors": flat})
        elif kind == wire.FEAT:
            nodes = np.asarray(payload["nodes"], np.int64)
            rows = self.store.gather_node_features(meta["node_set"], nodes)
            reply = (wire.FEATS, rows)
        else:
            raise wire.ProtocolError(f"unexpected frame kind {kind!r} on "
                                     "a shard-lookup connection")
        # count BEFORE the reply hits the wire: a client that has the
        # answer must observe the count (stats would otherwise lag reads)
        self.requests_served += 1
        wire.send_frame(conn, reply[0], {}, arrays=reply[1])

    def close(self) -> None:
        self._closed.set()
        self._lsock.close()
        with self._lock:
            conns, threads = list(self._conns), list(self._threads)
        for c in conns:
            c.close()
        for t in threads:
            t.join(timeout=5.0)


class RemoteShardClient:
    """Blocking request/response channel to one peer's `GraphShardServer`.

    One socket, one in-flight request (serialized under a lock — the
    sampler's frontier loop is sequential anyway).  Any transport error
    poisons the channel and surfaces as `ConnectionError`; the caller
    (`ShardedGraphStore`) decides whether to fall back locally."""

    def __init__(self, address: Address, *, request_timeout: float = 30.0,
                 connect_deadline: float = 20.0):
        self.address = (address[0], int(address[1]))
        self.request_timeout = request_timeout
        self.connect_deadline = connect_deadline
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()

    def request(self, kind: str, meta: dict,
                arrays: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        with self._lock:
            try:
                if self._sock is None:
                    self._sock = TcpTransport.connect(
                        self.address,
                        deadline=time.monotonic() + self.connect_deadline)
                wire.send_frame(self._sock, kind, meta, arrays=arrays)
                _, _, payload = wire.recv_frame(
                    self._sock, timeout=self.request_timeout,
                    frame_timeout=self.request_timeout)
            except (EOFError, OSError, wire.WireError) as exc:
                if self._sock is not None:
                    self._sock.close()
                    self._sock = None
                raise ConnectionError(
                    f"shard lookup to {self.address} failed: {exc}") from exc
            return payload if payload is not None else {}

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                self._sock.close()
                self._sock = None


class _LRU:
    """Bounded OrderedDict LRU (single-threaded: the sampler loop)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        try:
            self._d.move_to_end(key)
        except KeyError:
            self.misses += 1
            return None
        self.hits += 1
        return self._d[key]

    def put(self, key, value) -> None:
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)


class ShardedGraphStore(GraphStore):
    """Partitioned `GraphStore` view: shard-local lookups hit the local
    mmap, remote ones batch into one request per owning peer.

    ``fallback_local=True`` (the default) answers from the local mmap
    when a peer is unreachable — byte-identical data (all shards map the
    same `GraphDirectory`), so a dead peer costs locality, never
    correctness.  Peers that fail once are remembered dead; nothing here
    retries them (the fleet's rebalance owns recovery policy)."""

    def __init__(self, local: MmapGraphStore, shard: int, num_shards: int,
                 peers: Mapping[int, Address], *,
                 cache_entries: int = 1 << 16,
                 request_timeout: float = 30.0,
                 fallback_local: bool = True):
        if not 0 <= shard < num_shards:
            raise ValueError(f"shard {shard} out of range 0..{num_shards-1}")
        self.local = local
        self.shard = shard
        self.shard_map = ShardMap(local.num_nodes, num_shards)
        self.fallback_local = fallback_local
        self.request_timeout = request_timeout
        # the GraphStore surface, delegated to the local mmap
        self.schema = local.schema
        self.num_nodes = local.num_nodes
        self.node_features = local.node_features
        self.edges = local.edges
        self._index: dict = {}  # unused: neighbors* delegate below
        self._peers = {int(s): (a[0], int(a[1]))
                       for s, a in peers.items() if int(s) != shard}
        self._clients: dict[int, RemoteShardClient] = {}
        self._dead_peers: set[int] = set()
        self._cache = _LRU(cache_entries)
        self.stats = {"local": 0, "remote": 0, "cache_hits": 0,
                      "fallbacks": 0}

    # -- lookup plumbing -----------------------------------------------------

    def _client(self, shard: int) -> RemoteShardClient:
        if shard not in self._clients:
            self._clients[shard] = RemoteShardClient(
                self._peers[shard], request_timeout=self.request_timeout)
        return self._clients[shard]

    def _peer_usable(self, shard: int) -> bool:
        return shard in self._peers and shard not in self._dead_peers

    def _mark_dead(self, shard: int) -> None:
        self._dead_peers.add(shard)
        client = self._clients.pop(shard, None)
        if client is not None:
            client.close()

    def neighbors(self, edge_set: str, node: int) -> np.ndarray:
        return self.neighbors_batch(edge_set, np.asarray([node]))[0]

    def neighbors_batch(self, edge_set: str,
                        nodes: Sequence[int]) -> list[np.ndarray]:
        nodes = np.asarray(nodes, np.int64)
        src_set = self.schema.edge_sets[edge_set].source
        owners = self.shard_map.owner(src_set, nodes)
        out: list = [None] * len(nodes)
        remote: dict[int, list[int]] = {}
        for i, (u, s) in enumerate(zip(nodes, owners)):
            s = int(s)
            if s == self.shard or not self._peer_usable(s):
                out[i] = self.local.neighbors(edge_set, int(u))
                self.stats["local" if s == self.shard else "fallbacks"] += 1
                continue
            hit = self._cache.get((edge_set, int(u)))
            if hit is not None:
                out[i] = hit
                self.stats["cache_hits"] += 1
            else:
                remote.setdefault(s, []).append(i)
        for s, idxs in remote.items():
            req = nodes[idxs]
            try:
                reply = self._client(s).request(
                    wire.NBR, {"edge_set": edge_set}, {"nodes": req})
            except ConnectionError:
                if not self.fallback_local:
                    raise
                self._mark_dead(s)
                for i in idxs:
                    out[i] = self.local.neighbors(edge_set, int(nodes[i]))
                self.stats["fallbacks"] += len(idxs)
                continue
            self.stats["remote"] += len(idxs)
            offsets = np.zeros(len(idxs) + 1, np.int64)
            np.cumsum(np.asarray(reply["counts"], np.int64),
                      out=offsets[1:])
            flat = np.asarray(reply["neighbors"], np.int64)
            for j, i in enumerate(idxs):
                arr = flat[offsets[j]:offsets[j + 1]]
                out[i] = arr
                self._cache.put((edge_set, int(nodes[i])), arr)
        return out

    def gather_node_features(self, node_set: str,
                             ids: np.ndarray) -> dict[str, np.ndarray]:
        ids = np.asarray(ids, np.int64)
        spec = self.node_features.get(node_set, {})
        if not spec or ids.size == 0:
            return self.local.gather_node_features(node_set, ids)
        owners = self.shard_map.owner(node_set, ids)
        out = {k: np.empty((len(ids),) + v.shape[1:], v.dtype)
               for k, v in spec.items()}
        usable = np.asarray([s == self.shard or self._peer_usable(int(s))
                             for s in owners])
        local_mask = (owners == self.shard) | ~usable
        if local_mask.any():
            rows = self.local.gather_node_features(node_set,
                                                   ids[local_mask])
            for k in out:
                out[k][local_mask] = rows[k]
            self.stats["local"] += int((owners == self.shard).sum())
            self.stats["fallbacks"] += int((~usable).sum())
        for s in np.unique(owners[~local_mask]):
            s = int(s)
            mask = owners == s
            try:
                rows = self._client(s).request(
                    wire.FEAT, {"node_set": node_set}, {"nodes": ids[mask]})
            except ConnectionError:
                if not self.fallback_local:
                    raise
                self._mark_dead(s)
                rows = self.local.gather_node_features(node_set, ids[mask])
                self.stats["fallbacks"] += int(mask.sum())
            else:
                self.stats["remote"] += int(mask.sum())
            for k in out:
                out[k][mask] = rows[k]
        return out

    def close(self) -> None:
        for client in self._clients.values():
            client.close()
        self._clients.clear()

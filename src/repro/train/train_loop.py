"""Training step factory: loss, grad accumulation, remat, optimizer apply.

`make_train_step` returns a pure function suitable for jit/pjit:

    (params, opt_state, batch) -> (params, opt_state, metrics)

Microbatching (gradient accumulation) is a `lax.scan` over batch shards —
XLA overlaps the per-microbatch reduce-scatters with the next microbatch's
compute (latency hiding), which is the compute/comm-overlap story at scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

EXTRA_INPUT_KEYS = ("audio_embeds", "patch_embeds")


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: jnp.ndarray | None = None):
    """logits [B,S,V] fp32, labels [B,S] int32; mean over mask."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = (nll * mask).sum()
    denom = jnp.maximum(mask.sum(), 1.0)
    return total / denom, denom


def chunked_cross_entropy(apply_head: Callable, params, x, labels,
                          mask=None, *, seq_chunk: int = 512):
    """CE loss without ever materialising [B, S, V] logits.

    Scans over sequence chunks; the chunk body is rematerialised in the
    backward pass, so peak memory is one [B, seq_chunk, V] logits block.
    This is THE memory fix for large-vocab train cells (a 102k-vocab model
    at 1M tokens/step would otherwise need >25 GiB/device just for logits).
    """
    b, s, d = x.shape
    c = min(seq_chunk, s)
    while s % c:  # fall back to a divisor
        c -= 1
    n = s // c
    if n <= 1:
        logits = apply_head(params, x)
        return softmax_cross_entropy(logits, labels, mask)
    xs = jnp.moveaxis(x.reshape(b, n, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)
    ms = (jnp.moveaxis(mask.reshape(b, n, c), 1, 0)
          if mask is not None else jnp.ones((n, b, c), jnp.float32))

    def body(carry, inp):
        tot, den = carry
        xc, lc, mc = inp
        logits = apply_head(params, xc)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mc = mc.astype(jnp.float32)
        return (tot + ((logz - ll) * mc).sum(), den + mc.sum()), None

    (tot, den), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.zeros((), jnp.float32),
                               jnp.zeros((), jnp.float32)), (xs, ls, ms))
    den = jnp.maximum(den, 1.0)
    return tot / den, den


def make_loss_fn(model, cfg: ArchConfig, *, seq_chunk: int = 512) -> Callable:
    def loss_fn(params, batch):
        extras = {k: batch[k] for k in EXTRA_INPUT_KEYS if k in batch}
        x, aux = model.backbone(params, batch["tokens"], **extras)
        loss, denom = chunked_cross_entropy(
            model.apply_head, params, x, batch["labels"],
            batch.get("loss_mask"), seq_chunk=seq_chunk)
        total = loss
        if cfg.moe is not None:
            total = (total
                     + cfg.moe.aux_loss_weight * aux["moe_lb_loss"]
                     + cfg.moe.z_loss_weight * aux["moe_z_loss"])
        metrics = {"loss": loss, "total_loss": total, "tokens": denom}
        metrics.update(aux)
        return total, metrics

    return loss_fn


def _split_microbatches(batch, n_micro: int):
    def reshape(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree_util.tree_map(reshape, batch)


def make_train_step(model, cfg: ArchConfig, optimizer, *,
                    n_microbatches: int = 1,
                    grad_compression=None,
                    param_axes=None,
                    mesh=None,
                    plan=None,
                    zero1: bool = False) -> Callable:
    """Build the train step.

    With ``mesh`` (or a ``repro.distributed.partition.MeshPlan`` via
    ``plan``) the returned step is pjit'd for GSPMD partitioning: every
    batch leaf's leading dim is constrained over the mesh's data axes
    (GSPMD then partitions the loss and inserts the cross-replica gradient
    psum where sharded activations meet replicated/FSDP params), the body
    is traced under the plan's ``dispatch_context()`` so kernel
    eligibility budgets VMEM from per-shard — not global — batch shapes
    (rows / data shards, feature widths / model shards), and the rule
    tables are active (``use_sharding``) so grad/param constraints
    resolve.  ``zero1=True`` additionally constrains the optimizer state
    through the optimizer's ``state_axes`` — moments of "embed"-sharded
    params land "data"-sharded (ZeRO-1) and GSPMD gathers params only for
    the update.  Without a mesh the step is returned un-jitted, as before.
    """
    loss_fn = make_loss_fn(model, cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if param_axes is None:
        from repro.nn.module import Param
        tree = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        param_axes = jax.tree_util.tree_map(
            lambda p: p.axes, tree, is_leaf=lambda x: isinstance(x, Param))

    def constrain_grads(grads):
        from repro.distributed.sharding import constrain_tree
        return constrain_tree(grads, param_axes, kind="param")

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            micro = _split_microbatches(batch, n_microbatches)

            def accum(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, mb)
                grads = constrain_grads(grads)
                g_acc = constrain_grads(
                    jax.tree_util.tree_map(jnp.add, g_acc, grads))
                m_acc = jax.tree_util.tree_map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            # accumulate in the param dtype: for bf16-param giants (arctic)
            # an fp32 accumulator alone would be +7.5 GiB/device.
            g0 = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, p.dtype), params))
            m0 = {"loss": 0.0, "total_loss": 0.0, "tokens": 0.0,
                  "moe_lb_loss": 0.0, "moe_z_loss": 0.0,
                  "moe_drop_fraction": 0.0}
            m0 = {k: jnp.zeros((), jnp.float32) for k in m0}
            (grads, metrics), _ = jax.lax.scan(accum, (g0, m0), micro)
            inv = 1.0 / n_microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g * jnp.asarray(inv, g.dtype), grads)
            metrics = {k: v / n_microbatches for k, v in metrics.items()}
        else:
            (_, metrics), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)

        if grad_compression is not None:
            grads = grad_compression(grads)

        params, opt_state, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    if mesh is None and plan is None:
        return train_step

    from jax.sharding import NamedSharding
    from repro.distributed import partition
    from repro.distributed.sharding import constrain_tree, use_sharding
    if plan is None:
        plan = partition.plan_for(mesh)
    mesh = plan.mesh
    dp_size = plan.data_size
    batch_spec = plan.data_spec()
    state_axes = optimizer.state_axes(param_axes) if zero1 else None

    def constrain_batch(batch):
        def c(x):
            if x.ndim and x.shape[0] % dp_size == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, batch_spec))
            return x
        return jax.tree_util.tree_map(c, batch)

    def dp_step(params, opt_state, batch):
        with use_sharding(mesh, plan.param_rules, plan.act_rules), \
                plan.dispatch_context():
            if state_axes is not None:
                # ZeRO-1: keep the optimizer state "data"-sharded on both
                # sides of the update; GSPMD then gathers params only for
                # the update itself
                opt_state = constrain_tree(opt_state, state_axes,
                                           kind="param")
            params, opt_state, metrics = train_step(
                params, opt_state, constrain_batch(batch))
            if state_axes is not None:
                opt_state = constrain_tree(opt_state, state_axes,
                                           kind="param")
            return params, opt_state, metrics

    # donate replicated state: see partition.make_train_step
    return jax.jit(dp_step, donate_argnums=(0, 1))


def device_prefetch(batches, place: Callable | None = None, *,
                    plan=None, depth: int = 2):
    """Double-buffered host->device transfer.

    Wraps a host batch iterator so that ``place`` (device_put / sharded
    placement) for batch N+1 runs on a background thread while the caller
    is still dispatching step N — jax transfers are asynchronous, so the
    host->device copy (and, with the sampling service, the wire decode
    feeding it) overlaps the previous train step instead of serializing
    with it.  ``depth`` bounds the in-flight batches (device memory bound);
    2 = classic double buffering.  Exceptions in `batches`/`place` re-raise
    at the consumer and early close joins the thread (repro.data.pipeline
    prefetch semantics).

    Placement must match the train step's in_specs or the first step pays
    a resharding copy: pass a ``repro.distributed.partition.MeshPlan`` as
    ``plan`` (place defaults to ``plan.put_super_batch``, the correct 2-D
    sharding — groups over "data", feature dims over "model") or a
    ``place`` built from the same plan.  On a multi-process mesh the same
    wrapper overlaps the per-process global-array assembly
    (`make_array_from_process_local_data`) — and, with a
    `RemoteStreamClient` source, the TCP receive + wire decode — with the
    previous step.
    """
    from repro.data.pipeline import prefetch
    if place is None:
        if plan is None:
            raise ValueError("device_prefetch needs place= or plan=")
        place = plan.put_super_batch
    return prefetch((place(*b) for b in batches), depth=depth)


def make_eval_step(model, cfg: ArchConfig) -> Callable:
    loss_fn = make_loss_fn(model, cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


# ---------------------------------------------------------------------------
# Graph train/eval steps (the Trainer's step factories)
# ---------------------------------------------------------------------------

def make_graph_train_step(loss_fn: Callable, optimizer, *,
                          plan=None, num_groups: int | None = None
                          ) -> Callable:
    """(params, opt_state, graph, labels) -> (params, opt_state, loss).

    ``loss_fn(params, scalar_graph, labels) -> scalar``.  Without a plan:
    a plain jit'd value_and_grad + optimizer update (identical XLA program
    to the seed runner's inline step).  With a
    `repro.distributed.partition.MeshPlan`: delegates to
    ``partition.make_train_step`` (per-shard forward/backward over the 2-D
    mesh, gradient pmean, ZeRO-1 update) — ``num_groups`` is the
    super-batch stack size, required there.
    """
    if plan is not None:
        from repro.distributed import partition
        if num_groups is None:
            raise ValueError("make_graph_train_step with plan= needs "
                             "num_groups= (the super-batch stack size)")
        return partition.make_train_step(plan, loss_fn, optimizer,
                                         num_groups=num_groups)

    @jax.jit
    def train_step(params, opt_state, graph, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, graph, labels)
        params, opt_state, _ = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_graph_eval_step(metric_fn: Callable, *, plan=None) -> Callable:
    """(params, graph, labels) -> tuple of metric scalars.

    ``metric_fn(params, scalar_graph, labels)`` must return a TUPLE of
    scalars that are exact sums (numerators/denominators, not means) —
    with a plan they are summed over component groups and psum'd over
    data shards by ``partition.make_eval_step``, so only sums aggregate
    correctly across shardings.
    """
    if plan is not None:
        from repro.distributed import partition
        return partition.make_eval_step(plan, metric_fn)
    return jax.jit(metric_fn)

"""Optimizers (AdamW, Adafactor, SGD-momentum) + schedules + clipping.

No optax in this environment; implemented directly on param pytrees.
Moments may be stored in a reduced dtype (bf16) for the >=100B archs — an
explicit distributed-memory trick recorded in EXPERIMENTS.md.
Optimizer state reuses the params' logical sharding axes, so FSDP (ZeRO-3)
sharding of m/v falls out of the same rule table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


def constant_lr(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# Gradient clipping
# ---------------------------------------------------------------------------

def _sqsum(x) -> jnp.ndarray:
    """Sum of squares in fp32 without materialising an fp32 copy of huge
    leaves: chunk the reduction over the leading dim (the CPU pipeline does
    not fuse convert+square into the reduce for multi-GiB tensors)."""
    if x.size > 16 * 1024 * 1024 and x.ndim >= 2:
        return jax.lax.map(
            lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), x).sum()
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree: PyTree, *, axis_name=None,
                shard_dims: PyTree | None = None) -> jnp.ndarray:
    """L2 norm over a gradient tree.

    Under ZeRO-1 (repro.distributed.partition) each leaf may be this data
    shard's *slice*: pass ``axis_name`` (the data mesh axes) and
    ``shard_dims`` (per-leaf int, -1 = replicated) and the squared sum of
    sliced leaves is psum-corrected across shards, while replicated
    leaves contribute once — so every shard computes the exact full norm.
    """
    if axis_name is None or shard_dims is None:
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.sqrt(sum(_sqsum(x) for x in leaves))
    leaves = jax.tree_util.tree_leaves(tree)
    dims = jax.tree_util.tree_leaves(shard_dims)
    assert len(leaves) == len(dims), (len(leaves), len(dims))
    local = sum((_sqsum(x) for x, d in zip(leaves, dims) if d >= 0),
                jnp.zeros((), jnp.float32))
    repl = sum((_sqsum(x) for x, d in zip(leaves, dims) if d < 0),
               jnp.zeros((), jnp.float32))
    return jnp.sqrt(jax.lax.psum(local, axis_name) + repl)


def clip_by_global_norm(tree: PyTree, max_norm: float, *, axis_name=None,
                        shard_dims: PyTree | None = None):
    norm = global_norm(tree, axis_name=axis_name, shard_dims=shard_dims)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    # multiply in each leaf's own dtype: `g * f32_scalar` would otherwise
    # materialise an fp32 copy of the whole gradient tree.
    return jax.tree_util.tree_map(
        lambda g: g * scale.astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

# Leaves larger than this (elements, pre-sharding) have their elementwise
# update applied via lax.map over the leading (stacked-layer) dim: the fp32
# working copies then cover one layer slice at a time instead of the whole
# stacked tensor.  Crucial for the >=100B archs (arctic's stacked expert
# weight is 156B params; an fp32 temp of its per-device shard is 2.4 GiB —
# times several temps times three such leaves without chunking).
CHUNKED_UPDATE_THRESHOLD = 64 * 1024 * 1024


def _maybe_chunked(fn, *leaves):
    """Apply an elementwise-per-slice update leaf-wise, chunking the leading
    dim when the leaf is huge.  fn(*slices) -> tuple of slices."""
    lead = leaves[0]
    if lead.size <= CHUNKED_UPDATE_THRESHOLD or lead.ndim < 3:
        return fn(*leaves)
    return jax.lax.map(lambda xs: fn(*xs), leaves)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    max_grad_norm: float = 1.0

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(zeros, params),
                          jax.tree_util.tree_map(zeros, params))

    def update(self, grads: PyTree, state: AdamWState, params: PyTree, *,
               axis_name=None, shard_dims: PyTree | None = None
               ) -> tuple[PyTree, AdamWState, dict]:
        """ZeRO-1: with ``axis_name``/``shard_dims`` the inputs are this
        data shard's slices; AdamW's update is elementwise, so only the
        clipping norm needs the cross-shard psum correction."""
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm,
                                           axis_name=axis_name,
                                           shard_dims=shard_dims)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mh = m32 / bc1
            vh = v32 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (new_p.astype(p.dtype), m32.astype(self.moment_dtype),
                    v32.astype(self.moment_dtype))

        out = jax.tree_util.tree_map(
            lambda *ls: _maybe_chunked(upd, *ls),
            params, grads, state.m, state.v)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return (new_params, AdamWState(step, new_m, new_v),
                {"grad_norm": gnorm, "learning_rate": lr})

    def state_axes(self, param_axes: PyTree) -> "AdamWState":
        """Optimizer-state logical axes mirror the params'."""
        return AdamWState((), param_axes, param_axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; for the >=100B archs)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: PyTree  # row second-moment (or full v for <2D leaves)
    vc: PyTree  # col second-moment (or unused zeros)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: Callable | float = 1e-3
    decay: float = 0.8  # beta2 exponent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    # T5X-style: no global grad-norm clip — Adafactor's rms_u update clip
    # substitutes, and skipping it avoids full-gradient-tree fp32 temps.
    max_grad_norm: float | None = None

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params: PyTree) -> AdafactorState:
        def vr(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if self._factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(jnp.zeros((), jnp.int32),
                              jax.tree_util.tree_map(vr, params),
                              jax.tree_util.tree_map(vc, params))

    def update(self, grads, state, params, *, axis_name=None,
               shard_dims: PyTree | None = None):
        """ZeRO-1: with ``axis_name``/``shard_dims`` the inputs are this
        data shard's slices.  Unlike AdamW the factored statistics are
        not elementwise — any mean that reduces over a sliced dim (the
        column stats and rms normalizers of a row-sliced 2-D leaf) is
        pmean-corrected so every shard reproduces the replicated math."""
        if self.max_grad_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm,
                                               axis_name=axis_name,
                                               shard_dims=shard_dims)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        step = state.step + 1
        beta2 = 1.0 - step.astype(jnp.float32) ** (-self.decay)
        lr = self._lr(step)

        def upd(p, g, vr, vc, shard_dim=-1):
            # shard_dim >= 0: leaf is a ZeRO slice along that dim (slices
            # are equal-sized, so pmean-of-means is the global mean)
            def corr(x, over_dim):
                if axis_name is not None and shard_dim == over_dim:
                    return jax.lax.pmean(x, axis_name)
                return x
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if self._factored(p):
                vr_n = beta2 * vr + (1 - beta2) * corr(
                    g2.mean(axis=-1), p.ndim - 1)
                vc_n = beta2 * vc + (1 - beta2) * corr(
                    g2.mean(axis=-2), p.ndim - 2)
                rbar = corr(vr_n.mean(axis=-1, keepdims=True), p.ndim - 2)
                denom = (vr_n / jnp.maximum(rbar, self.eps))[..., None] \
                    * vc_n[..., None, :]
                u = g32 * jax.lax.rsqrt(denom + self.eps)
            else:
                vr_n = beta2 * vr + (1 - beta2) * g2
                vc_n = vc
                u = g32 * jax.lax.rsqrt(vr_n + self.eps)
            msq = jnp.mean(jnp.square(u))
            if axis_name is not None and shard_dim >= 0:
                msq = jax.lax.pmean(msq, axis_name)
            rms_u = jnp.sqrt(msq + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            new_p = (p.astype(jnp.float32) - lr *
                     (u + self.weight_decay * p.astype(jnp.float32)))
            return new_p.astype(p.dtype), vr_n, vc_n

        # chunked update keeps fp32 working copies to one layer slice;
        # NB the rms_u clip then applies per leading-dim slice (documented).
        # ZeRO slices skip chunking (they are 1/n_shards-sized already).
        dims = (shard_dims if shard_dims is not None
                else jax.tree_util.tree_map(lambda p: -1, params))
        out = jax.tree_util.tree_map(
            lambda p, g, vr, vc, d: (upd(p, g, vr, vc, d) if d >= 0
                                     else _maybe_chunked(upd, p, g, vr, vc)),
            params, grads, state.vr, state.vc, dims)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return (pick(0), AdafactorState(step, pick(1), pick(2)),
                {"grad_norm": gnorm, "learning_rate": lr})

    def state_axes(self, param_axes: PyTree) -> "AdafactorState":
        def vr_ax(ax):
            return tuple(ax[:-1]) if len(ax) >= 2 else tuple(ax)

        def vc_ax(ax):
            return tuple(ax[:-2]) + tuple(ax[-1:]) if len(ax) >= 2 else ()

        t = lambda f: jax.tree_util.tree_map(
            f, param_axes, is_leaf=lambda x: isinstance(x, tuple))
        return AdafactorState((), t(vr_ax), t(vc_ax))


def make_optimizer(kind: str, lr, *, total_steps: int = 10000,
                   warmup: int = 200, moment_dtype=jnp.float32,
                   weight_decay: float = 0.1):
    sched = warmup_cosine(lr, warmup, total_steps)
    if kind == "adamw":
        return AdamW(learning_rate=sched, moment_dtype=moment_dtype,
                     weight_decay=weight_decay)
    if kind == "adafactor":
        return Adafactor(learning_rate=sched, weight_decay=weight_decay)
    raise ValueError(kind)

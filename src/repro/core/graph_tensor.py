"""GraphTensor — the paper's §3.2 data structure, adapted to JAX/TPU.

Hardware adaptation (see DESIGN.md §2): XLA requires static shapes, so the
jit-visible GraphTensor is always *fixed-capacity*: every node/edge set has a
static capacity (array length) and a dynamic `sizes` vector giving the valid
item count per graph component.  Ragged data lives at the host/data-pipeline
layer (numpy lists); `repro.data.batching` merges and pads into this form —
exactly the paper's "padding graph + weight 0" recipe for Cloud TPUs.

Registered as a pytree: feature dicts / sizes / adjacency are leaves, all
names are static aux data, so GraphTensors pass through jit/grad/vmap/scan.

jax is OPTIONAL here: the numpy-only sampler-worker children
(`repro.sampling_service.worker` and its import closure, enforced by
tools/repro_lint rule PUR005) build, stack and ship GraphTensors without
an accelerator runtime.  Without jax every array op falls back to numpy
and pytree registration is a no-op; `stack_graphs`/`unstack_graph` use a
structural map with identical semantics (same error message, same leaf
order).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Mapping, Optional, Sequence

import numpy as np

try:  # trainer processes have jax; sampler workers must not need it.
    # REPRO_NO_JAX=1 opts a process into the numpy-only fallback even
    # when jax IS installed — sampler workers (fork or dial-in) set it
    # to keep their RSS at interpreter+numpy+touched-pages instead of
    # paying a few hundred MB for an accelerator runtime they never use.
    if os.environ.get("REPRO_NO_JAX"):
        raise ImportError("jax disabled by REPRO_NO_JAX")
    import jax
    import jax.numpy as jnp
except ImportError:  # pragma: no cover — exercised by the jax-blocked
    #                   subprocess test in tests/test_worker_numpy_only.py
    jax = None
    jnp = np

Array = Any


def _register_pytree(cls):
    """jax pytree registration, a no-op in numpy-only processes."""
    if jax is not None:
        return jax.tree_util.register_pytree_node_class(cls)
    return cls


def _freeze(d: Mapping) -> dict:
    return dict(sorted(d.items()))


@_register_pytree
@dataclasses.dataclass
class Context:
    """Per-component features. sizes[c] == 1 for real components, 0 for
    padding components (doubles as the training-weight mask)."""

    sizes: Array                      # [C] int32 (1 = real, 0 = padding)
    features: dict[str, Array]        # each [C, ...]

    def tree_flatten(self):
        feats = _freeze(self.features)
        return (self.sizes, tuple(feats.values())), tuple(feats.keys())

    @classmethod
    def tree_unflatten(cls, keys, children):
        sizes, feats = children[0], children[1]
        return cls(sizes, dict(zip(keys, feats)))

    @property
    def num_components(self) -> int:
        return self.sizes.shape[0]

    def __getitem__(self, name: str) -> Array:
        return self.features[name]

    def mask(self) -> Array:
        return self.sizes > 0


@_register_pytree
@dataclasses.dataclass
class NodeSet:
    sizes: Array                      # [C] int32 — valid nodes per component
    features: dict[str, Array]        # each [capacity, ...]
    capacity: int                     # static array length

    def tree_flatten(self):
        feats = _freeze(self.features)
        return ((self.sizes, tuple(feats.values())),
                (tuple(feats.keys()), self.capacity))

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, capacity = aux
        sizes, feats = children
        return cls(sizes, dict(zip(keys, feats)), capacity)

    @property
    def total_size(self) -> Array:
        return self.sizes.sum()

    def __getitem__(self, name: str) -> Array:
        return self.features[name]

    def mask(self) -> Array:
        """[capacity] bool — True for valid (non-padding) nodes."""
        return jnp.arange(self.capacity) < self.total_size

    def component_ids(self) -> Array:
        """[capacity] int32 — component index per node (jit-safe)."""
        bounds = jnp.cumsum(self.sizes)
        return jnp.searchsorted(bounds, jnp.arange(self.capacity),
                                side="right").astype(jnp.int32)


@_register_pytree
@dataclasses.dataclass
class Adjacency:
    source: Array                     # [capacity] int32 node indices
    target: Array                     # [capacity] int32 node indices
    source_name: str
    target_name: str

    def tree_flatten(self):
        return ((self.source, self.target),
                (self.source_name, self.target_name))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])


@_register_pytree
@dataclasses.dataclass
class EdgeSet:
    sizes: Array                      # [C] int32 — valid edges per component
    adjacency: Adjacency
    features: dict[str, Array]
    capacity: int

    def tree_flatten(self):
        feats = _freeze(self.features)
        return ((self.sizes, self.adjacency, tuple(feats.values())),
                (tuple(feats.keys()), self.capacity))

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, capacity = aux
        sizes, adjacency, feats = children
        return cls(sizes, adjacency, dict(zip(keys, feats)), capacity)

    @property
    def total_size(self) -> Array:
        return self.sizes.sum()

    def __getitem__(self, name: str) -> Array:
        return self.features[name]

    def mask(self) -> Array:
        return jnp.arange(self.capacity) < self.total_size

    def component_ids(self) -> Array:
        bounds = jnp.cumsum(self.sizes)
        return jnp.searchsorted(bounds, jnp.arange(self.capacity),
                                side="right").astype(jnp.int32)


@_register_pytree
@dataclasses.dataclass
class GraphTensor:
    """A scalar GraphTensor (shape []) holding one merged batch of graphs
    as components — the paper's canonical in-model representation."""

    context: Context
    node_sets: dict[str, NodeSet]
    edge_sets: dict[str, EdgeSet]

    def tree_flatten(self):
        ns = _freeze(self.node_sets)
        es = _freeze(self.edge_sets)
        return ((self.context, tuple(ns.values()), tuple(es.values())),
                (tuple(ns.keys()), tuple(es.keys())))

    @classmethod
    def tree_unflatten(cls, aux, children):
        nkeys, ekeys = aux
        context, nvals, evals = children
        return cls(context, dict(zip(nkeys, nvals)), dict(zip(ekeys, evals)))

    # -- conveniences -------------------------------------------------------

    @property
    def num_components(self) -> int:
        return self.context.num_components

    def replace_features(
            self,
            context: Optional[Mapping[str, Array]] = None,
            node_sets: Optional[Mapping[str, Mapping[str, Array]]] = None,
            edge_sets: Optional[Mapping[str, Mapping[str, Array]]] = None,
    ) -> "GraphTensor":
        """New GraphTensor with some feature dicts replaced (paper §3.2)."""
        new_ctx = self.context
        if context is not None:
            new_ctx = Context(self.context.sizes, dict(context))
        new_ns = dict(self.node_sets)
        for name, feats in (node_sets or {}).items():
            old = new_ns[name]
            new_ns[name] = NodeSet(old.sizes, dict(feats), old.capacity)
        new_es = dict(self.edge_sets)
        for name, feats in (edge_sets or {}).items():
            old = new_es[name]
            new_es[name] = EdgeSet(old.sizes, old.adjacency, dict(feats),
                                   old.capacity)
        return GraphTensor(new_ctx, new_ns, new_es)

    @classmethod
    def from_pieces(cls, context: Context | None = None,
                    node_sets: Mapping[str, NodeSet] | None = None,
                    edge_sets: Mapping[str, EdgeSet] | None = None
                    ) -> "GraphTensor":
        node_sets = dict(node_sets or {})
        edge_sets = dict(edge_sets or {})
        if context is None:
            context = Context(jnp.ones((1,), jnp.int32), {})
        return cls(context, node_sets, edge_sets)


# ---------------------------------------------------------------------------
# Super-batch stacking (data parallelism over padded component groups)
# ---------------------------------------------------------------------------
#
# A *stacked* GraphTensor carries `R` structurally identical padded graphs
# ("component groups") on a leading axis: every leaf gains a [R, ...] leading
# dim while the static aux data (names, capacities) stays per-group.  It is a
# transport container for sharding over a device mesh's "data" axis — graph
# ops must not run on it directly; `unstack_graph` (or a shard_map body that
# slices its local group) restores scalar GraphTensors first.

def _graph_structure(g: GraphTensor) -> tuple:
    """Hashable structural fingerprint — the numpy-only stand-in for
    jax's treedef (set names, capacities, feature keys, endpoint names)."""
    return (
        tuple(sorted(g.context.features)),
        tuple((name, ns.capacity, tuple(sorted(ns.features)))
              for name, ns in sorted(g.node_sets.items())),
        tuple((name, es.capacity, tuple(sorted(es.features)),
               es.adjacency.source_name, es.adjacency.target_name)
              for name, es in sorted(g.edge_sets.items())),
    )


def _map_graphs(fn, graphs: "Sequence[GraphTensor]") -> GraphTensor:
    """Structural tree-map over same-shaped GraphTensors, leaf by leaf —
    `fn` receives one leaf per input graph, in input order.  Mirrors the
    pytree leaf layout exactly (jax-free path for sampler workers)."""
    g0 = graphs[0]
    ctx = Context(fn(*[g.context.sizes for g in graphs]),
                  {k: fn(*[g.context.features[k] for g in graphs])
                   for k in g0.context.features})
    node_sets = {}
    for name, ns0 in g0.node_sets.items():
        sets = [g.node_sets[name] for g in graphs]
        node_sets[name] = NodeSet(
            fn(*[s.sizes for s in sets]),
            {k: fn(*[s.features[k] for s in sets]) for k in ns0.features},
            ns0.capacity)
    edge_sets = {}
    for name, es0 in g0.edge_sets.items():
        sets = [g.edge_sets[name] for g in graphs]
        adj = Adjacency(fn(*[s.adjacency.source for s in sets]),
                        fn(*[s.adjacency.target for s in sets]),
                        es0.adjacency.source_name,
                        es0.adjacency.target_name)
        edge_sets[name] = EdgeSet(
            fn(*[s.sizes for s in sets]), adj,
            {k: fn(*[s.features[k] for s in sets]) for k in es0.features},
            es0.capacity)
    return GraphTensor(ctx, node_sets, edge_sets)


def stack_graphs(graphs: "Sequence[GraphTensor]") -> GraphTensor:
    """Stack structurally identical padded GraphTensors on a new leading
    axis.  All inputs must share one treedef (same set names, capacities,
    feature keys) — i.e. be padded to the same SizeConstraints."""
    if not graphs:
        raise ValueError("stack_graphs: empty sequence")
    if jax is not None:
        treedefs = {jax.tree_util.tree_structure(g) for g in graphs}
    else:
        treedefs = {_graph_structure(g) for g in graphs}
    if len(treedefs) != 1:
        raise ValueError(
            "stack_graphs: inputs are not structurally identical "
            f"(got {len(treedefs)} distinct treedefs; pad every group to "
            "the same SizeConstraints first)")

    def _stack(*leaves):
        if all(isinstance(x, np.ndarray) for x in leaves):
            return np.stack(leaves)
        return jnp.stack([jnp.asarray(x) for x in leaves])

    if jax is not None:
        return jax.tree_util.tree_map(_stack, *graphs)
    return _map_graphs(_stack, graphs)


def stack_size(graph: GraphTensor) -> Optional[int]:
    """Number of stacked component groups, or None for a scalar
    GraphTensor.  Discriminates on context.sizes rank ([C] vs [R, C])."""
    ndim = getattr(graph.context.sizes, "ndim", 1)
    return int(graph.context.sizes.shape[0]) if ndim == 2 else None


def unstack_graph(graph: GraphTensor) -> "list[GraphTensor]":
    """Invert :func:`stack_graphs`: split the leading group axis back into
    scalar GraphTensors (index, don't copy — works on jit/shard_map
    tracers)."""
    n = graph.context.sizes.shape[0]
    if jax is not None:
        return [jax.tree_util.tree_map(lambda x, i=i: x[i], graph)
                for i in range(n)]
    return [_map_graphs(lambda x, i=i: x[i], [graph]) for i in range(n)]


HIDDEN_STATE = "hidden_state"
SOURCE = "source"
TARGET = "target"
CONTEXT = "context"

"""repro.core — the TF-GNN data model + modeling API in JAX.

API levels (paper Fig. 1):
  L1 data:      GraphSchema, GraphTensor (+ repro.data batching/padding)
  L2 exchange:  broadcast_*/pool_*/segment_softmax (repro.core.ops)
  L3 modeling:  Conv classes, GraphUpdate, model zoo
  L4 orchestration: repro.orchestration.runner
"""
from repro.core.graph_tensor import (Adjacency, Context, EdgeSet,  # noqa
                                     GraphTensor, NodeSet, CONTEXT,
                                     HIDDEN_STATE, SOURCE, TARGET)
from repro.core.schema import (FeatureSpec, GraphSchema, NodeSetSpec,  # noqa
                               EdgeSetSpec, mag_schema, recsys_schema)
from repro.core import ops  # noqa
from repro.core.ops import (broadcast_node_to_edges, pool_edges_to_node,  # noqa
                            broadcast_context_to_nodes,
                            broadcast_context_to_edges,
                            pool_nodes_to_context, pool_edges_to_context,
                            segment_softmax, node_degree, use_kernels)
from repro.core.convolutions import (AnyToAnyConv, GATv2Conv, GCNConv,  # noqa
                                     MultiHeadAttentionConv, SAGEConv,
                                     SimpleConv)
from repro.core.graph_update import (ContextUpdate, EdgeSetUpdate,  # noqa
                                     GraphUpdate, MapFeatures,
                                     NextStateFromConcat, NodeSetUpdate,
                                     ResidualNextState, SingleInputNextState)
from repro.core import models  # noqa

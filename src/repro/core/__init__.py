"""repro.core — the TF-GNN data model + modeling API in JAX.

API levels (paper Fig. 1):
  L1 data:      GraphSchema, GraphTensor (+ repro.data batching/padding)
  L2 exchange:  broadcast_*/pool_*/segment_softmax (repro.core.ops)
  L3 modeling:  Conv classes, GraphUpdate, model zoo
  L4 orchestration: repro.orchestration.runner

Import-laziness contract (enforced by tools/repro_lint rule PUR005):
importing this package — which happens whenever ANY ``repro.core.*``
submodule is imported — must not drag in jax, because the numpy-only
sampler workers load the L1 data model (`graph_tensor`, `schema`)
through here.  The convenience re-exports below therefore resolve
lazily via PEP 562 module ``__getattr__``: ``from repro.core import
GATv2Conv`` still works everywhere, but only pulls the jax-heavy L2/L3
modules when actually used.
"""
from importlib import import_module

# name -> defining submodule; "" marks the submodule itself as the export
_EXPORTS = {
    # L1 data model (jax-free by contract)
    "Adjacency": "graph_tensor", "Context": "graph_tensor",
    "EdgeSet": "graph_tensor", "GraphTensor": "graph_tensor",
    "NodeSet": "graph_tensor", "CONTEXT": "graph_tensor",
    "HIDDEN_STATE": "graph_tensor", "SOURCE": "graph_tensor",
    "TARGET": "graph_tensor",
    "FeatureSpec": "schema", "GraphSchema": "schema",
    "NodeSetSpec": "schema", "EdgeSetSpec": "schema",
    "mag_schema": "schema", "recsys_schema": "schema",
    # L2 exchange ops (jax)
    "ops": "",
    "broadcast_node_to_edges": "ops", "pool_edges_to_node": "ops",
    "broadcast_context_to_nodes": "ops",
    "broadcast_context_to_edges": "ops",
    "pool_nodes_to_context": "ops", "pool_edges_to_context": "ops",
    "segment_softmax": "ops", "node_degree": "ops", "use_kernels": "ops",
    # L3 modeling (jax)
    "AnyToAnyConv": "convolutions", "GATv2Conv": "convolutions",
    "GCNConv": "convolutions", "MultiHeadAttentionConv": "convolutions",
    "SAGEConv": "convolutions", "SimpleConv": "convolutions",
    "ContextUpdate": "graph_update", "EdgeSetUpdate": "graph_update",
    "GraphUpdate": "graph_update", "MapFeatures": "graph_update",
    "NextStateFromConcat": "graph_update",
    "NodeSetUpdate": "graph_update", "ResidualNextState": "graph_update",
    "SingleInputNextState": "graph_update",
    "models": "",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        submodule = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    if submodule == "":
        value = import_module(f"{__name__}.{name}")
    else:
        value = getattr(import_module(f"{__name__}.{submodule}"), name)
    globals()[name] = value  # cache: __getattr__ runs once per name
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

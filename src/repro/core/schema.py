"""GraphSchema — the abstract definition of a heterogeneous graph (paper §3.1).

A schema declares node sets, edge sets (with source/target node-set names)
and context features; each feature has a dtype and a feature shape (the
dims after the leading item dim).  The schema never holds data.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    dtype: str                        # "int32" | "float32" | ...
    shape: tuple[int, ...] = ()       # per-item feature dims (may be ())

    def to_np_dtype(self):
        return np.dtype(self.dtype)


@dataclasses.dataclass(frozen=True)
class NodeSetSpec:
    features: Mapping[str, FeatureSpec] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EdgeSetSpec:
    source: str
    target: str
    features: Mapping[str, FeatureSpec] = dataclasses.field(
        default_factory=dict)


@dataclasses.dataclass(frozen=True)
class GraphSchema:
    node_sets: Mapping[str, NodeSetSpec]
    edge_sets: Mapping[str, EdgeSetSpec]
    context: Mapping[str, FeatureSpec] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        for name, es in self.edge_sets.items():
            if es.source not in self.node_sets:
                raise ValueError(
                    f"edge set {name!r}: unknown source {es.source!r}")
            if es.target not in self.node_sets:
                raise ValueError(
                    f"edge set {name!r}: unknown target {es.target!r}")

    # -- (de)serialization (the tf.Example/proto analogue is JSON here) -----

    def to_json(self) -> str:
        def fs(d):
            return {k: {"dtype": v.dtype, "shape": list(v.shape)}
                    for k, v in d.items()}

        return json.dumps({
            "node_sets": {k: {"features": fs(v.features)}
                          for k, v in self.node_sets.items()},
            "edge_sets": {k: {"source": v.source, "target": v.target,
                              "features": fs(v.features)}
                          for k, v in self.edge_sets.items()},
            "context": fs(self.context),
        }, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "GraphSchema":
        raw = json.loads(text)

        def fs(d):
            return {k: FeatureSpec(v["dtype"], tuple(v["shape"]))
                    for k, v in d.items()}

        return cls(
            node_sets={k: NodeSetSpec(fs(v.get("features", {})))
                       for k, v in raw["node_sets"].items()},
            edge_sets={k: EdgeSetSpec(v["source"], v["target"],
                                      fs(v.get("features", {})))
                       for k, v in raw["edge_sets"].items()},
            context=fs(raw.get("context", {})))


def mag_schema() -> GraphSchema:
    """The OGBN-MAG schema from the paper's case study (§8, Fig. 5)."""
    f32 = lambda *s: FeatureSpec("float32", tuple(s))
    i32 = lambda *s: FeatureSpec("int32", tuple(s))
    return GraphSchema(
        node_sets={
            "paper": NodeSetSpec({"feat": f32(128), "labels": i32(),
                                  "year": i32()}),
            "author": NodeSetSpec({"id": i32()}),
            "institution": NodeSetSpec({"id": i32()}),
            "field_of_study": NodeSetSpec({"id": i32()}),
        },
        edge_sets={
            "cites": EdgeSetSpec("paper", "paper"),
            "writes": EdgeSetSpec("author", "paper"),
            "written": EdgeSetSpec("paper", "author"),
            "affiliated_with": EdgeSetSpec("author", "institution"),
            "has_topic": EdgeSetSpec("paper", "field_of_study"),
        })


def recsys_schema() -> GraphSchema:
    """The recommender example schema from the paper (§3.1, Fig. 2a)."""
    f32 = lambda *s: FeatureSpec("float32", tuple(s))
    i32 = lambda *s: FeatureSpec("int32", tuple(s))
    return GraphSchema(
        node_sets={
            "items": NodeSetSpec({"category": i32(), "price": f32(3)}),
            "users": NodeSetSpec({"name": i32(), "age": i32(),
                                  "country": i32()}),
        },
        edge_sets={
            "purchased": EdgeSetSpec("items", "users"),
            "is-friend": EdgeSetSpec("users", "users"),
        },
        context={"scores": f32(4)})

"""GraphUpdate (paper §4.2.2, Eq. 1–3): one round of heterogeneous message
passing assembled from per-edge-set Convs and per-node-set NextState maps,
plus optional edge-set and context updates (full Graph Networks)."""
from __future__ import annotations

from typing import Callable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.core import ops
from repro.core.graph_tensor import (CONTEXT, GraphTensor, HIDDEN_STATE,
                                     SOURCE, TARGET)
from repro.nn.layers import ACTIVATIONS, Linear, LayerNorm
from repro.nn.module import Module


class NextStateFromConcat(Module):
    """next_state = fn(concat(old state, all inputs)) (paper Fig. 7)."""

    def __init__(self, in_dim: int, units: int, *, activation: str = "relu",
                 use_layer_norm: bool = False):
        self.dense = Linear(in_dim, units)
        self.act = ACTIVATIONS[activation]
        self.norm = LayerNorm(units) if use_layer_norm else None

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p = {"dense": self.dense.init(k1)}
        if self.norm is not None:
            p["norm"] = self.norm.init(k2)
        return p

    def __call__(self, params, old_state, inputs: list):
        x = jnp.concatenate([old_state] + list(inputs), axis=-1)
        y = self.act(self.dense(params["dense"], x))
        if self.norm is not None:
            y = self.norm(params["norm"], y)
        return y


class ResidualNextState(Module):
    """next_state = old + fn(concat(...)); used by deeper GNN stacks."""

    def __init__(self, in_dim: int, units: int, *, activation: str = "relu"):
        self.inner = NextStateFromConcat(in_dim, units, activation=activation)

    def init(self, key):
        return {"inner": self.inner.init(key)}

    def __call__(self, params, old_state, inputs: list):
        return old_state + self.inner(params["inner"], old_state, inputs)


class SingleInputNextState(Module):
    """Passes through the single pooled message (paper GCN Eq. 4)."""

    def init(self, key):
        return {}

    def __call__(self, params, old_state, inputs: list):
        assert len(inputs) == 1
        return inputs[0]


class NodeSetUpdate(Module):
    """{edge_set_name: Conv} + NextState for one node set (paper Eq. 1).

    Convs that expose a fused kernel path (e.g. SimpleConv's `edge_mpnn`
    route via repro.kernels.dispatch) use it transparently — each conv is
    invoked with the full graph, so a whole message-passing round runs
    fused when every conv in the round is dispatch-eligible; see
    `describe_dispatch` for which path each conv takes and why.
    """

    def __init__(self, convs: Mapping[str, Module], next_state: Module):
        self.convs = dict(sorted(convs.items()))
        self.next_state = next_state

    def describe_dispatch(self, params, graph: GraphTensor) -> dict:
        """{edge_set_name: dispatch Decision (or None for generic convs)}."""
        return {name: (conv.fused_decision(params["convs"][name], graph,
                                           name)
                       if hasattr(conv, "fused_decision") else None)
                for name, conv in self.convs.items()}

    def init(self, key):
        keys = jax.random.split(key, len(self.convs) + 1)
        return {
            "convs": {name: conv.init(k)
                      for (name, conv), k in zip(self.convs.items(), keys)},
            "next_state": self.next_state.init(keys[-1]),
        }

    def __call__(self, params, graph: GraphTensor, node_set_name: str):
        old = graph.node_sets[node_set_name][HIDDEN_STATE]
        pooled = [conv(params["convs"][name], graph, name)
                  for name, conv in self.convs.items()]
        return self.next_state(params["next_state"], old, pooled)


class EdgeSetUpdate(Module):
    """Materialised per-edge state update (paper Eq. 3, NextEdgeState)."""

    def __init__(self, in_dim: int, units: int, *, activation: str = "relu",
                 use_receiver_state: bool = True,
                 use_sender_state: bool = True):
        self.next_state = NextStateFromConcat(in_dim, units,
                                              activation=activation)
        self.use_receiver_state = use_receiver_state
        self.use_sender_state = use_sender_state

    def init(self, key):
        return {"next_state": self.next_state.init(key)}

    def __call__(self, params, graph: GraphTensor, edge_set_name: str):
        es = graph.edge_sets[edge_set_name]
        inputs = []
        if self.use_sender_state:
            inputs.append(ops.broadcast_node_to_edges(
                graph, edge_set_name, SOURCE, feature_name=HIDDEN_STATE))
        if self.use_receiver_state:
            inputs.append(ops.broadcast_node_to_edges(
                graph, edge_set_name, TARGET, feature_name=HIDDEN_STATE))
        old = es.features.get(HIDDEN_STATE)
        if old is None:
            old = inputs[0]
            inputs = inputs[1:]
        return self.next_state(params["next_state"], old, inputs)


class ContextUpdate(Module):
    """Pool node states per component and update the context state."""

    def __init__(self, node_set_names: list[str], in_dim: int, units: int,
                 *, reduce_type: str = "mean", activation: str = "relu"):
        self.node_set_names = list(node_set_names)
        self.reduce_type = reduce_type
        self.next_state = NextStateFromConcat(in_dim, units,
                                              activation=activation)

    def init(self, key):
        return {"next_state": self.next_state.init(key)}

    def __call__(self, params, graph: GraphTensor):
        pooled = [ops.pool_nodes_to_context(graph, name, self.reduce_type,
                                            feature_name=HIDDEN_STATE)
                  for name in self.node_set_names]
        old = graph.context.features.get(HIDDEN_STATE)
        if old is None:
            old = pooled[0]
            pooled = pooled[1:]
        return self.next_state(params["next_state"], old, pooled)


class GraphUpdate(Module):
    """One message-passing round over the whole heterogeneous graph.

    Applies (in order): edge-set updates, node-set updates, context update —
    the Graph Networks schedule generalised to named sets.  Each returns a
    new GraphTensor with replaced hidden states.

    With kernels enabled (repro.core.ops.use_kernels / REPRO_KERNELS) the
    hot path of a round — gather, per-edge message, scatter-pool — runs
    through the Pallas kernels behind repro.kernels.dispatch;
    `describe_dispatch` reports the per-conv routing decisions.
    """

    def __init__(self, *,
                 node_sets: Mapping[str, NodeSetUpdate] | None = None,
                 edge_sets: Mapping[str, EdgeSetUpdate] | None = None,
                 context: ContextUpdate | None = None):
        self.node_sets = dict(sorted((node_sets or {}).items()))
        self.edge_sets = dict(sorted((edge_sets or {}).items()))
        self.context = context

    def init(self, key):
        n = len(self.node_sets) + len(self.edge_sets) + 1
        keys = jax.random.split(key, n)
        i = 0
        p = {"node_sets": {}, "edge_sets": {}}
        for name, upd in self.edge_sets.items():
            p["edge_sets"][name] = upd.init(keys[i])
            i += 1
        for name, upd in self.node_sets.items():
            p["node_sets"][name] = upd.init(keys[i])
            i += 1
        if self.context is not None:
            p["context"] = self.context.init(keys[i])
        return p

    def describe_dispatch(self, params, graph: GraphTensor) -> dict:
        """{node_set_name: {edge_set_name: dispatch Decision | None}} —
        which kernel path each conv of this round would take on `graph`."""
        return {name: upd.describe_dispatch(params["node_sets"][name],
                                            graph)
                for name, upd in self.node_sets.items()
                if hasattr(upd, "describe_dispatch")}

    def __call__(self, params, graph: GraphTensor) -> GraphTensor:
        if self.edge_sets:
            new_edge_feats = {}
            for name, upd in self.edge_sets.items():
                feats = dict(graph.edge_sets[name].features)
                feats[HIDDEN_STATE] = upd(params["edge_sets"][name], graph,
                                          name)
                new_edge_feats[name] = feats
            graph = graph.replace_features(edge_sets=new_edge_feats)
        if self.node_sets:
            new_node_feats = {}
            for name, upd in self.node_sets.items():
                feats = dict(graph.node_sets[name].features)
                feats[HIDDEN_STATE] = upd(params["node_sets"][name], graph,
                                          name)
                new_node_feats[name] = feats
            graph = graph.replace_features(node_sets=new_node_feats)
        if self.context is not None:
            feats = dict(graph.context.features)
            feats[HIDDEN_STATE] = self.context(params["context"], graph)
            graph = graph.replace_features(context=feats)
        return graph


class MapFeatures(Module):
    """Per-set feature transformations (paper §4.2.1).

    fns: {"node_sets": {name: callable(params, feats)->feats}, ...} where
    each callable is a Module; used to build initial hidden states.
    """

    def __init__(self, node_sets: Mapping[str, Module] | None = None,
                 edge_sets: Mapping[str, Module] | None = None,
                 context: Module | None = None):
        self.node_sets = dict(sorted((node_sets or {}).items()))
        self.edge_sets = dict(sorted((edge_sets or {}).items()))
        self.context = context

    def init(self, key):
        n = len(self.node_sets) + len(self.edge_sets) + 1
        keys = jax.random.split(key, n)
        i = 0
        p = {"node_sets": {}, "edge_sets": {}}
        for name, fn in self.node_sets.items():
            p["node_sets"][name] = fn.init(keys[i])
            i += 1
        for name, fn in self.edge_sets.items():
            p["edge_sets"][name] = fn.init(keys[i])
            i += 1
        if self.context is not None:
            p["context"] = self.context.init(keys[i])
        return p

    def __call__(self, params, graph: GraphTensor) -> GraphTensor:
        node_feats = {
            name: fn(params["node_sets"][name],
                     graph.node_sets[name].features)
            for name, fn in self.node_sets.items()}
        edge_feats = {
            name: fn(params["edge_sets"][name],
                     graph.edge_sets[name].features)
            for name, fn in self.edge_sets.items()}
        ctx = (self.context(params["context"], graph.context.features)
               if self.context is not None else None)
        return graph.replace_features(
            context=ctx,
            node_sets=node_feats or None,
            edge_sets=edge_feats or None)

"""Bundled GNN model collection (paper §4.3 / §8 and Table 1 baselines).

Each model factory takes the *graph structure* (node sets, edge sets with
their endpoints) plus widths, and returns a Module whose __call__ maps a
GraphTensor (with "hidden_state" features) to an updated GraphTensor after
`num_rounds` of message passing.  These are the concrete instantiations of
GraphUpdate used by the OGBN-MAG case study and the benchmarks.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.convolutions import (GATv2Conv, GCNConv,
                                     MultiHeadAttentionConv, SAGEConv,
                                     SimpleConv)
from repro.core.graph_tensor import (GraphTensor, HIDDEN_STATE, SOURCE,
                                     TARGET)
from repro.core.graph_update import (GraphUpdate, NextStateFromConcat,
                                     NodeSetUpdate, SingleInputNextState)
from repro.core.schema import GraphSchema
from repro.nn.layers import Linear
from repro.nn.module import Module


def incident_edge_sets(schema_edges: Mapping[str, tuple[str, str]],
                       node_set: str) -> list[str]:
    """Edge sets whose TARGET is `node_set` (the receiving convention)."""
    return [name for name, (src, tgt) in schema_edges.items()
            if tgt == node_set]


class GNNStack(Module):
    """A sequence of GraphUpdate rounds (optionally weight-shared)."""

    def __init__(self, updates: Sequence[GraphUpdate], *,
                 share_weights: bool = False):
        self.updates = list(updates)
        self.share_weights = share_weights

    def init(self, key):
        if self.share_weights:
            return {"rounds": [self.updates[0].init(key)] * len(self.updates)}
        keys = jax.random.split(key, len(self.updates))
        return {"rounds": [u.init(k) for u, k in zip(self.updates, keys)]}

    def __call__(self, params, graph: GraphTensor) -> GraphTensor:
        for upd, p in zip(self.updates, params["rounds"]):
            graph = upd(p, graph)
        return graph


def vanilla_mpnn(edges: Mapping[str, tuple[str, str]],
                 node_dims: Mapping[str, int], *,
                 message_dim: int = 128, hidden_dim: int = 128,
                 num_rounds: int = 4, reduce_type: str = "sum",
                 receiver_tag: str = TARGET,
                 use_layer_norm: bool = True,
                 skip_node_sets: Sequence[str] = ()) -> GNNStack:
    """The paper's §8 VanillaMPNN: per-edge-set SimpleConv + per-node-set
    NextStateFromConcat (Fig. 7/8), generalised over an arbitrary schema."""
    updates = []
    for rnd in range(num_rounds):
        node_updates = {}
        for ns, dim in node_dims.items():
            if ns in skip_node_sets:
                continue
            convs = {}
            for es, (src, tgt) in edges.items():
                if (tgt if receiver_tag == TARGET else src) != ns:
                    continue
                sender = src if receiver_tag == TARGET else tgt
                in_dim = node_dims[sender] + dim if rnd == 0 else \
                    hidden_dim * 2
                # after round 0 all states are hidden_dim wide
                sender_dim = node_dims[sender] if rnd == 0 else hidden_dim
                recv_dim = dim if rnd == 0 else hidden_dim
                convs[es] = SimpleConv(message_dim, sender_dim + recv_dim,
                                       reduce_type=reduce_type,
                                       receiver_tag=receiver_tag)
            if not convs:
                continue
            recv_dim = dim if rnd == 0 else hidden_dim
            next_in = recv_dim + message_dim * len(convs)
            node_updates[ns] = NodeSetUpdate(
                convs, NextStateFromConcat(next_in, hidden_dim,
                                           use_layer_norm=use_layer_norm))
        updates.append(GraphUpdate(node_sets=node_updates))
    return GNNStack(updates)


def rgcn(edges: Mapping[str, tuple[str, str]],
         node_dims: Mapping[str, int], *, hidden_dim: int = 128,
         num_rounds: int = 2) -> GNNStack:
    """R-GCN (paper Eq. 5): per-edge-set mean-pooled linear messages plus a
    self-transform, summed."""

    class RGCNNextState(Module):
        def __init__(self, in_dim):
            self.w_self = Linear(in_dim, hidden_dim, use_bias=False)

        def init(self, key):
            return {"w_self": self.w_self.init(key)}

        def __call__(self, params, old, inputs):
            return jax.nn.relu(
                sum(inputs) + self.w_self(params["w_self"], old))

    updates = []
    for rnd in range(num_rounds):
        node_updates = {}
        for ns, dim in node_dims.items():
            convs = {}
            for es, (src, tgt) in edges.items():
                if tgt != ns:
                    continue
                sender_dim = node_dims[src] if rnd == 0 else hidden_dim
                convs[es] = SAGEConv(hidden_dim, sender_dim,
                                     aggregator="mean")
            if not convs:
                continue
            recv_dim = dim if rnd == 0 else hidden_dim
            node_updates[ns] = NodeSetUpdate(convs, RGCNNextState(recv_dim))
        updates.append(GraphUpdate(node_sets=node_updates))
    return GNNStack(updates)


def gcn(edge_set: str, node_set: str, in_dim: int, *,
        hidden_dim: int = 64, num_rounds: int = 2) -> GNNStack:
    """Homogeneous GCN (paper Eq. 4) — expects self-loops in the data."""
    updates = []
    for rnd in range(num_rounds):
        conv = GCNConv(hidden_dim, in_dim if rnd == 0 else hidden_dim)
        updates.append(GraphUpdate(node_sets={
            node_set: NodeSetUpdate({edge_set: conv},
                                    SingleInputNextState())}))
    return GNNStack(updates)


def graph_sage(edges: Mapping[str, tuple[str, str]],
               node_dims: Mapping[str, int], *, hidden_dim: int = 128,
               num_rounds: int = 2, aggregator: str = "mean") -> GNNStack:
    updates = []
    for rnd in range(num_rounds):
        node_updates = {}
        for ns, dim in node_dims.items():
            convs = {}
            for es, (src, tgt) in edges.items():
                if tgt != ns:
                    continue
                sender_dim = node_dims[src] if rnd == 0 else hidden_dim
                convs[es] = SAGEConv(hidden_dim, sender_dim,
                                     aggregator=aggregator)
            if not convs:
                continue
            recv_dim = dim if rnd == 0 else hidden_dim
            node_updates[ns] = NodeSetUpdate(
                convs, NextStateFromConcat(
                    recv_dim + hidden_dim * len(convs), hidden_dim))
        updates.append(GraphUpdate(node_sets=node_updates))
    return GNNStack(updates)


def gatv2(edges: Mapping[str, tuple[str, str]],
          node_dims: Mapping[str, int], *, num_heads: int = 4,
          per_head: int = 32, num_rounds: int = 2) -> GNNStack:
    """Heterogeneous GATv2 (paper §4.3: the GAT→R-GCN-style generalisation:
    attention within each edge set, relation importance via separate
    weights)."""
    hidden = num_heads * per_head
    updates = []
    for rnd in range(num_rounds):
        node_updates = {}
        for ns, dim in node_dims.items():
            convs = {}
            for es, (src, tgt) in edges.items():
                if tgt != ns:
                    continue
                in_dim = node_dims[src] if rnd == 0 else hidden
                # GATv2Conv queries use receiver dim; align by projecting
                convs[es] = GATv2Conv(num_heads, per_head,
                                      dim if rnd == 0 else hidden)
            if not convs:
                continue
            recv_dim = dim if rnd == 0 else hidden
            node_updates[ns] = NodeSetUpdate(
                convs, NextStateFromConcat(
                    recv_dim + hidden * len(convs), hidden))
        updates.append(GraphUpdate(node_sets=node_updates))
    return GNNStack(updates)


def hgt_like(edges: Mapping[str, tuple[str, str]],
             node_dims: Mapping[str, int], *, num_heads: int = 4,
             per_head: int = 32, num_rounds: int = 2) -> GNNStack:
    """Heterogeneous transformer-conv stack (the paper's Table-1 competitor
    family: per-edge-set dot-product attention, per-type projections)."""
    hidden = num_heads * per_head
    updates = []
    for rnd in range(num_rounds):
        node_updates = {}
        for ns, dim in node_dims.items():
            convs = {}
            for es, (src, tgt) in edges.items():
                if tgt != ns:
                    continue
                convs[es] = MultiHeadAttentionConv(
                    num_heads, per_head, dim if rnd == 0 else hidden)
            if not convs:
                continue
            recv_dim = dim if rnd == 0 else hidden
            node_updates[ns] = NodeSetUpdate(
                convs, NextStateFromConcat(
                    recv_dim + hidden * len(convs), hidden))
        updates.append(GraphUpdate(node_sets=node_updates))
    return GNNStack(updates)

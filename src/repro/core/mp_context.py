"""Trace-time model-parallel context — the core-layer hook the 2-D
partitioning plan (repro.distributed.partition.MeshPlan) drives.

Lives at the core layer (dependency-free besides jax) so `repro.core.ops`
can consume it without importing `repro.distributed` — the plan *sets*
the context around its shard_map bodies, the ops *read* it to split the
feature axis and place the cross-device all-gather at the pool boundary.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax

_tls = threading.local()


@dataclasses.dataclass(frozen=True)
class ModelContext:
    """The model axis as visible inside a shard_map body.  `split` takes
    this device's feature chunk, `gather` is the boundary all-gather."""

    axis: str
    size: int

    def can_split(self, x) -> bool:
        return (getattr(x, "ndim", 0) >= 2
                and x.shape[-1] % self.size == 0
                and x.shape[-1] >= self.size)

    def split(self, x):
        w = x.shape[-1] // self.size
        i = jax.lax.axis_index(self.axis)
        return jax.lax.dynamic_slice_in_dim(x, i * w, w, axis=x.ndim - 1)

    def gather(self, x):
        return jax.lax.all_gather(x, self.axis, axis=x.ndim - 1, tiled=True)


@contextlib.contextmanager
def model_parallel_trace(axis: Optional[str], size: int):
    """Make the model axis visible to `repro.core.ops` while tracing a
    shard_map body.  No-op for size <= 1 (the 1-D data-parallel path)."""
    prev = getattr(_tls, "mp", None)
    _tls.mp = ModelContext(axis, size) if axis and size > 1 else None
    try:
        yield _tls.mp
    finally:
        _tls.mp = prev


def current_model_context() -> Optional[ModelContext]:
    return getattr(_tls, "mp", None)

"""Graph convolutions (paper §4.2.2 Eq. 2 / §4.3 / Appendix A.4).

`AnyToAnyConv` is the unified base of the paper's Appendix A.4: a Conv
computes messages from senders (nodes and/or edge features) and pools them
at a receiver, where the receiver may be the edge set's SOURCE or TARGET
node set, or the CONTEXT.  GATv2Conv subclasses it exactly as in the paper.

All convs take (params, graph, edge_set_name[, receiver_tag]) and return
the pooled message tensor shaped like a feature of the receiver set.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import ops
from repro.core.graph_tensor import (CONTEXT, GraphTensor, HIDDEN_STATE,
                                     SOURCE, TARGET)
from repro.kernels import dispatch as kernel_dispatch
from repro.nn.layers import Linear, ACTIVATIONS
from repro.nn.module import Module, Param

_OTHER = {SOURCE: TARGET, TARGET: SOURCE}


class AnyToAnyConv(Module):
    """Base class handling the broadcast/pool plumbing for all receiver
    kinds; subclasses implement `convolve`."""

    def __init__(self, *, receiver_tag: str = TARGET,
                 receiver_feature: str | None = HIDDEN_STATE,
                 sender_node_feature: str | None = HIDDEN_STATE,
                 sender_edge_feature: str | None = None):
        self.receiver_tag = receiver_tag
        self.receiver_feature = receiver_feature
        self.sender_node_feature = sender_node_feature
        self.sender_edge_feature = sender_edge_feature

    @property
    def takes_sender_node_input(self) -> bool:
        return self.sender_node_feature is not None

    @property
    def takes_sender_edge_input(self) -> bool:
        return self.sender_edge_feature is not None

    def __call__(self, params, graph: GraphTensor, edge_set_name: str):
        tag = self.receiver_tag
        es = graph.edge_sets[edge_set_name]
        if tag == CONTEXT:
            # receivers are graph components; senders are the edges' items
            def broadcast_from_receiver(value):
                return ops.broadcast_context_to_edges(graph, edge_set_name,
                                                      feature_value=value)

            def pool_to_receiver(value, reduce_type="sum"):
                return ops.pool_edges_to_context(graph, edge_set_name,
                                                 reduce_type,
                                                 feature_value=value)

            def extra_softmax(value):
                comp = es.component_ids()
                # reuse segment softmax over components
                c = graph.num_components
                mask = es.mask()
                mb = mask.reshape(mask.shape + (1,) * (value.ndim - 1))
                scores = jnp.where(mb, value, -jnp.inf)
                m = jax.ops.segment_max(scores, comp, num_segments=c)
                m = jnp.where(jnp.isfinite(m), m, 0)
                e = jnp.where(mb, jnp.exp(scores - jnp.take(m, comp, 0)), 0)
                z = jax.ops.segment_sum(e, comp, num_segments=c)
                return e / jnp.maximum(jnp.take(z, comp, 0), 1e-37)

            receiver_input = (graph.context[self.receiver_feature]
                              if self.receiver_feature else None)
            sender_node_input = None
            if self.takes_sender_node_input:
                sender_node_input = ops.broadcast_node_to_edges(
                    graph, edge_set_name, SOURCE,
                    feature_name=self.sender_node_feature)
        else:
            sender_tag = _OTHER[tag]

            def broadcast_from_receiver(value):
                return ops.broadcast_node_to_edges(graph, edge_set_name, tag,
                                                   feature_value=value)

            def pool_to_receiver(value, reduce_type="sum"):
                return ops.pool_edges_to_node(graph, edge_set_name, tag,
                                              reduce_type,
                                              feature_value=value)

            def extra_softmax(value):
                return ops.segment_softmax(graph, edge_set_name, tag,
                                           feature_value=value)

            receiver_name = (es.adjacency.target_name if tag == TARGET
                             else es.adjacency.source_name)
            receiver_input = (
                graph.node_sets[receiver_name][self.receiver_feature]
                if self.receiver_feature else None)
            sender_node_input = None
            if self.takes_sender_node_input:
                sender_node_input = ops.broadcast_node_to_edges(
                    graph, edge_set_name, sender_tag,
                    feature_name=self.sender_node_feature)
        sender_edge_input = (es[self.sender_edge_feature]
                             if self.takes_sender_edge_input else None)
        return self.convolve(
            params,
            sender_node_input=sender_node_input,
            sender_edge_input=sender_edge_input,
            receiver_input=receiver_input,
            broadcast_from_receiver=broadcast_from_receiver,
            pool_to_receiver=pool_to_receiver,
            extra_receiver_ops={"softmax": extra_softmax},
            edge_mask=es.mask())

    def convolve(self, params, *, sender_node_input, sender_edge_input,
                 receiver_input, broadcast_from_receiver, pool_to_receiver,
                 extra_receiver_ops, edge_mask):  # pragma: no cover
        raise NotImplementedError


class SimpleConv(AnyToAnyConv):
    """message = message_fn(concat(sender inputs[, receiver state])),
    then reduce — the paper's Fig. 7 `MyConv` generalised.

    When the conv has the fused shape (node-to-node, sum-pooled, no edge
    feature, receiver state combined) it routes the whole
    gather->message-MLP->scatter round through the Pallas `edge_mpnn`
    kernel via `repro.kernels.dispatch`; otherwise (or when dispatch deems
    the call ineligible) it runs the generic broadcast/pool path.
    """

    def __init__(self, units: int, in_dim: int, *, reduce_type: str = "sum",
                 combine_receiver: bool = True, activation: str = "relu",
                 **kwargs):
        super().__init__(**kwargs)
        self.reduce_type = reduce_type
        self.combine_receiver = combine_receiver
        self.message_fn = Linear(in_dim, units, kernel_axes=(None, None))
        self.activation_name = activation
        self.act = ACTIVATIONS[activation]

    def init(self, key):
        return {"message": self.message_fn.init(key)}

    def fused_decision(self, params, graph: GraphTensor,
                       edge_set_name: str) -> kernel_dispatch.Decision:
        """Dispatch decision for running this conv as one fused kernel."""
        if self.receiver_tag == CONTEXT:
            return kernel_dispatch.Decision(False, "context receiver")
        if self.sender_edge_feature is not None:
            return kernel_dispatch.Decision(False, "edge feature input")
        if self.sender_node_feature is None:
            return kernel_dispatch.Decision(False, "no sender node input")
        if not (self.combine_receiver and self.receiver_feature):
            return kernel_dispatch.Decision(False, "no receiver state")
        if self.reduce_type != "sum":
            return kernel_dispatch.Decision(
                False, f"{self.reduce_type} pooling not fused")
        es = graph.edge_sets[edge_set_name]
        sender_name, recv_name = self._fused_endpoints(es)
        h_src = graph.node_sets[sender_name][self.sender_node_feature]
        h_tgt = graph.node_sets[recv_name][self.receiver_feature]
        if h_src.ndim != 2 or h_tgt.ndim != 2:
            return kernel_dispatch.Decision(False, "non-2D node states")
        if h_src.dtype != h_tgt.dtype:
            # the generic path would promote via concat; keep it there
            return kernel_dispatch.Decision(False, "mixed state dtypes")
        w = params["message"]["w"]
        if w.shape[0] != h_src.shape[1] + h_tgt.shape[1]:
            return kernel_dispatch.Decision(False, "in_dim mismatch")
        # same inputs dispatch.edge_mpnn re-checks in __call__: capacities
        # as node counts, so the two decisions cannot diverge
        return kernel_dispatch.edge_mpnn_decision(
            graph.node_sets[sender_name].capacity,
            graph.node_sets[recv_name].capacity,
            h_src.shape[1], h_tgt.shape[1],
            w.shape[1], h_src.dtype, self.activation_name,
            n_edges=int(es.adjacency.source.shape[0]),
            sorted_ids=self._sorted_hint())

    def _fused_endpoints(self, es):
        if self.receiver_tag == TARGET:
            return es.adjacency.source_name, es.adjacency.target_name
        return es.adjacency.target_name, es.adjacency.source_name

    def _sorted_hint(self):
        """The BatchPlan layout bit sorts edges by TARGET; a SOURCE
        receiver scatters by source ids, which that sort leaves unsorted."""
        return None if self.receiver_tag == TARGET else False

    def __call__(self, params, graph: GraphTensor, edge_set_name: str):
        if not self.fused_decision(params, graph, edge_set_name).use_kernel:
            return super().__call__(params, graph, edge_set_name)
        es = graph.edge_sets[edge_set_name]
        adj = es.adjacency
        sender_idx, recv_idx = ((adj.source, adj.target)
                                if self.receiver_tag == TARGET
                                else (adj.target, adj.source))
        sender_name, recv_name = self._fused_endpoints(es)
        h_src = graph.node_sets[sender_name][self.sender_node_feature]
        h_tgt = graph.node_sets[recv_name][self.receiver_feature]
        n_tgt = graph.node_sets[recv_name].capacity
        w = params["message"]["w"].astype(h_src.dtype)
        b = params["message"]["b"].astype(h_src.dtype)
        tgt = jnp.where(es.mask(), recv_idx, n_tgt)  # padding -> dropped
        return kernel_dispatch.edge_mpnn(
            h_src, h_tgt, sender_idx, tgt, w, b,
            n_src=graph.node_sets[sender_name].capacity, n_tgt=n_tgt,
            activation=self.activation_name,
            sorted_ids=self._sorted_hint())

    def convolve(self, params, *, sender_node_input, sender_edge_input,
                 receiver_input, broadcast_from_receiver, pool_to_receiver,
                 extra_receiver_ops, edge_mask):
        parts = []
        if sender_node_input is not None:
            parts.append(sender_node_input)
        if sender_edge_input is not None:
            parts.append(sender_edge_input)
        if self.combine_receiver and receiver_input is not None:
            parts.append(broadcast_from_receiver(receiver_input))
        msg = self.act(self.message_fn(params["message"],
                                       jnp.concatenate(parts, axis=-1)))
        return pool_to_receiver(msg, reduce_type=self.reduce_type)


class GCNConv(AnyToAnyConv):
    """Kipf & Welling graph convolution with 1/sqrt(d_u d_v) normalisation
    (paper Eq. 4).  Self-loops are the caller's choice (add_self_loops in
    the data layer); degree counts include only valid edges."""

    def __init__(self, units: int, in_dim: int, *, use_bias: bool = False,
                 edge_set_name: str | None = None, **kwargs):
        super().__init__(**kwargs)
        self.units = units
        self.w = Linear(in_dim, units, use_bias=use_bias,
                        kernel_axes=(None, None))

    def init(self, key):
        return {"w": self.w.init(key)}

    def __call__(self, params, graph: GraphTensor, edge_set_name: str):
        tag = self.receiver_tag
        es = graph.edge_sets[edge_set_name]
        sender_tag = _OTHER[tag]
        h = graph.node_sets[es.adjacency.source_name
                            if sender_tag == SOURCE else
                            es.adjacency.target_name][HIDDEN_STATE]
        wh = self.w(params["w"], h)
        deg_r = ops.node_degree(graph, edge_set_name, tag)
        deg_s = ops.node_degree(graph, edge_set_name, sender_tag)
        inv_r = jax.lax.rsqrt(jnp.maximum(deg_r, 1).astype(wh.dtype))
        inv_s = jax.lax.rsqrt(jnp.maximum(deg_s, 1).astype(wh.dtype))
        msg = ops.broadcast_node_to_edges(
            graph, edge_set_name, sender_tag,
            feature_value=wh * inv_s[:, None])
        pooled = ops.pool_edges_to_node(graph, edge_set_name, tag, "sum",
                                        feature_value=msg)
        return pooled * inv_r[:, None]

    def convolve(self, *a, **k):  # unified entry not used
        raise NotImplementedError


class SAGEConv(AnyToAnyConv):
    """GraphSAGE aggregator (mean or max-pool variants, Hamilton et al.)."""

    def __init__(self, units: int, in_dim: int, *,
                 aggregator: str = "mean", hidden: int | None = None,
                 **kwargs):
        super().__init__(**kwargs)
        self.aggregator = aggregator
        self.w = Linear(in_dim, units, use_bias=False,
                        kernel_axes=(None, None))
        self.pool_mlp = (Linear(in_dim, hidden or in_dim)
                         if aggregator == "pool" else None)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p = {"w": self.w.init(k1)}
        if self.pool_mlp is not None:
            p["pool"] = self.pool_mlp.init(k2)
        return p

    def convolve(self, params, *, sender_node_input, sender_edge_input,
                 receiver_input, broadcast_from_receiver, pool_to_receiver,
                 extra_receiver_ops, edge_mask):
        msg = sender_node_input
        if self.aggregator == "pool":
            msg = jax.nn.relu(self.pool_mlp(params["pool"], msg))
            pooled = pool_to_receiver(msg, reduce_type="max")
        else:
            pooled = pool_to_receiver(msg, reduce_type="mean")
        return self.w(params["w"], pooled)


class GATv2Conv(AnyToAnyConv):
    """GATv2 attention conv — faithful port of the paper's Appendix A.4."""

    def __init__(self, num_heads: int, per_head_channels: int, in_dim: int,
                 *, edge_in_dim: int | None = None,
                 attention_activation: str = "leaky_relu",
                 activation: str = "relu", **kwargs):
        super().__init__(**kwargs)
        self.num_heads = num_heads
        self.per_head = per_head_channels
        out = num_heads * per_head_channels
        self.w_query = Linear(in_dim, out, kernel_axes=(None, None))
        self.w_sender_node = (Linear(in_dim, out, kernel_axes=(None, None))
                              if self.takes_sender_node_input else None)
        self.w_sender_edge = (
            Linear(edge_in_dim or in_dim, out, use_bias=False,
                   kernel_axes=(None, None))
            if self.takes_sender_edge_input else None)
        self.attention_activation = (
            (lambda x: jax.nn.leaky_relu(x, 0.2))
            if attention_activation == "leaky_relu"
            else ACTIVATIONS[attention_activation])
        self.act = ACTIVATIONS[activation]

    def init(self, key):
        ks = jax.random.split(key, 4)
        p = {"w_query": self.w_query.init(ks[0]),
             "attn_logits": Param(
                 jax.random.normal(ks[3], (self.num_heads, self.per_head))
                 * (self.per_head ** -0.5), (None, None))}
        if self.w_sender_node is not None:
            p["w_sender_node"] = self.w_sender_node.init(ks[1])
        if self.w_sender_edge is not None:
            p["w_sender_edge"] = self.w_sender_edge.init(ks[2])
        return p

    def _split(self, t):
        return t.reshape(*t.shape[:-1], self.num_heads, self.per_head)

    def convolve(self, params, *, sender_node_input, sender_edge_input,
                 receiver_input, broadcast_from_receiver, pool_to_receiver,
                 extra_receiver_ops, edge_mask):
        query = broadcast_from_receiver(
            self._split(self.w_query(params["w_query"], receiver_input)))
        value_terms = []
        if sender_node_input is not None:
            value_terms.append(self._split(
                self.w_sender_node(params["w_sender_node"],
                                   sender_node_input)))
        if sender_edge_input is not None:
            value_terms.append(self._split(
                self.w_sender_edge(params["w_sender_edge"],
                                   sender_edge_input)))
        value = sum(value_terms)
        feats = self.attention_activation(query + value)
        logits = jnp.einsum("...hc,hc->...h", feats,
                            params["attn_logits"].astype(feats.dtype))
        coef = extra_receiver_ops["softmax"](logits)
        messages = value * coef[..., None]
        pooled = pool_to_receiver(messages, reduce_type="sum")
        return self.act(pooled.reshape(*pooled.shape[:-2], -1))


class MultiHeadAttentionConv(AnyToAnyConv):
    """Transformer-style dot-product attention on edges (paper §4.3)."""

    def __init__(self, num_heads: int, per_head_channels: int, in_dim: int,
                 **kwargs):
        super().__init__(**kwargs)
        self.num_heads = num_heads
        self.per_head = per_head_channels
        out = num_heads * per_head_channels
        self.wq = Linear(in_dim, out, use_bias=False, kernel_axes=(None, None))
        self.wk = Linear(in_dim, out, use_bias=False, kernel_axes=(None, None))
        self.wv = Linear(in_dim, out, use_bias=False, kernel_axes=(None, None))

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"wq": self.wq.init(k1), "wk": self.wk.init(k2),
                "wv": self.wv.init(k3)}

    def convolve(self, params, *, sender_node_input, sender_edge_input,
                 receiver_input, broadcast_from_receiver, pool_to_receiver,
                 extra_receiver_ops, edge_mask):
        q = broadcast_from_receiver(
            self._split(self.wq(params["wq"], receiver_input)))
        k = self._split(self.wk(params["wk"], sender_node_input))
        v = self._split(self.wv(params["wv"], sender_node_input))
        logits = (q * k).sum(-1) * (self.per_head ** -0.5)
        coef = extra_receiver_ops["softmax"](logits)
        pooled = pool_to_receiver(v * coef[..., None], reduce_type="sum")
        return pooled.reshape(*pooled.shape[:-2], -1)

    def _split(self, t):
        return t.reshape(*t.shape[:-1], self.num_heads, self.per_head)

"""Data-exchange ops (paper §4.1, API Level 2).

Broadcast and pool between node sets, edge sets and context.  All ops work
on the fixed-capacity GraphTensor: padding items are masked out of every
reduction, so results over valid items match the ragged semantics of the
paper exactly (tested in tests/test_ops.py against a dense-adjacency
oracle).

Index-based exchange (gather/segment ops) is the paper's core design choice
vs. adjacency matmuls; the Pallas kernels in repro.kernels provide the
TPU-tuned fused path, enabled via `use_kernels(True)` or the REPRO_KERNELS
env var (the jnp path remains the reference oracle).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.graph_tensor import (CONTEXT, GraphTensor, HIDDEN_STATE,
                                     SOURCE, TARGET)

_KERNELS_ENABLED = os.environ.get("REPRO_KERNELS", "0") == "1"


def use_kernels(enabled: bool) -> None:
    global _KERNELS_ENABLED
    _KERNELS_ENABLED = enabled


def kernels_enabled() -> bool:
    return _KERNELS_ENABLED


def _edge_endpoint(graph: GraphTensor, edge_set_name: str, tag: str):
    es = graph.edge_sets[edge_set_name]
    adj = es.adjacency
    if tag == SOURCE:
        return adj.source, adj.source_name
    if tag == TARGET:
        return adj.target, adj.target_name
    raise ValueError(f"tag must be SOURCE or TARGET, got {tag!r}")


def _resolve_feature(piece, feature_name, feature_value):
    if (feature_name is None) == (feature_value is None):
        raise ValueError("exactly one of feature_name/feature_value required")
    return piece[feature_name] if feature_name is not None else feature_value


# ---------------------------------------------------------------------------
# node <-> edge
# ---------------------------------------------------------------------------

def broadcast_node_to_edges(graph: GraphTensor, edge_set_name: str, tag: str,
                            *, feature_name: str | None = None,
                            feature_value=None):
    """For each edge, the feature value at its `tag` endpoint node."""
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    value = _resolve_feature(graph.node_sets[node_set_name], feature_name,
                             feature_value)
    return jnp.take(value, idx, axis=0)


_SEGMENT_REDUCERS = {
    "sum": jax.ops.segment_sum,
    "mean": None,  # sum / count
    "max": jax.ops.segment_max,
    "min": jax.ops.segment_min,
    "prod": jax.ops.segment_prod,
}

_NEUTRAL = {"max": -jnp.inf, "min": jnp.inf}


def pool_edges_to_node(graph: GraphTensor, edge_set_name: str, tag: str,
                       reduce_type: str = "sum", *,
                       feature_name: str | None = None, feature_value=None):
    """Aggregate per-edge values at each `tag` endpoint node (paper Eq. 3).

    Padding edges are excluded; for max/min the neutral element is used and
    nodes with no (valid) incident edges yield 0.
    """
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    value = _resolve_feature(es, feature_name, feature_value)
    num_nodes = graph.node_sets[node_set_name].capacity
    emask = es.mask()
    emask_b = emask.reshape(emask.shape + (1,) * (value.ndim - 1))

    if reduce_type in ("sum", "mean"):
        data = jnp.where(emask_b, value, 0)
        if _KERNELS_ENABLED and value.ndim == 2 \
                and jnp.issubdtype(value.dtype, jnp.floating):
            from repro.kernels.segment_pool import ops as seg_ops
            pooled = seg_ops.segment_sum(data, idx, num_nodes)
        else:
            pooled = jax.ops.segment_sum(data, idx, num_segments=num_nodes)
        if reduce_type == "mean":
            cnt = jax.ops.segment_sum(emask.astype(value.dtype), idx,
                                      num_segments=num_nodes)
            shape = cnt.shape + (1,) * (value.ndim - 1)
            pooled = pooled / jnp.maximum(cnt, 1).reshape(shape)
        return pooled
    if reduce_type in ("max", "min"):
        neutral = _NEUTRAL[reduce_type]
        data = jnp.where(emask_b, value, neutral)
        fn = _SEGMENT_REDUCERS[reduce_type]
        pooled = fn(data, idx, num_segments=num_nodes)
        return jnp.where(jnp.isfinite(pooled), pooled, 0)
    raise ValueError(f"unknown reduce_type {reduce_type!r}")


def segment_softmax(graph: GraphTensor, edge_set_name: str, tag: str,
                    *, feature_value):
    """Softmax of per-edge scores within each receiver node's edge segment
    (the attention-pooling primitive used by GATv2/transformer convs)."""
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    num_nodes = graph.node_sets[node_set_name].capacity
    emask = es.mask()
    emask_b = emask.reshape(emask.shape + (1,) * (feature_value.ndim - 1))
    scores = jnp.where(emask_b, feature_value, -jnp.inf)
    if _KERNELS_ENABLED and scores.ndim == 2 \
            and jnp.issubdtype(scores.dtype, jnp.floating):
        # fused path: segment max + exp-sum via the Pallas segment kernel
        from repro.kernels.segment_pool import ops as seg_ops
        kidx = jnp.where(emask, idx, num_nodes)
        seg_max = seg_ops.segment_max(
            jnp.where(emask_b, scores, 0), kidx, num_nodes)
        seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0)
        shifted = jnp.where(emask_b,
                            scores - jnp.take(seg_max, idx, axis=0), -jnp.inf)
        exp = jnp.where(emask_b, jnp.exp(shifted), 0)
        seg_sum = seg_ops.segment_sum(exp, kidx, num_nodes)
        denom = jnp.take(seg_sum, idx, axis=0)
        return exp / jnp.maximum(denom, 1e-37)
    seg_max = jax.ops.segment_max(scores, idx, num_segments=num_nodes)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0)
    shifted = jnp.where(emask_b, scores - jnp.take(seg_max, idx, axis=0),
                        -jnp.inf)
    exp = jnp.where(emask_b, jnp.exp(shifted), 0)
    seg_sum = jax.ops.segment_sum(exp, idx, num_segments=num_nodes)
    denom = jnp.take(seg_sum, idx, axis=0)
    return exp / jnp.maximum(denom, 1e-37)


# ---------------------------------------------------------------------------
# context <-> node/edge
# ---------------------------------------------------------------------------

def _piece(graph: GraphTensor, name: str, node_or_edge: str):
    return (graph.node_sets[name] if node_or_edge == "node"
            else graph.edge_sets[name])


def broadcast_context_to_nodes(graph: GraphTensor, node_set_name: str, *,
                               feature_name: str | None = None,
                               feature_value=None):
    value = _resolve_feature(graph.context, feature_name, feature_value)
    comp = graph.node_sets[node_set_name].component_ids()
    return jnp.take(value, jnp.minimum(comp, value.shape[0] - 1), axis=0)


def broadcast_context_to_edges(graph: GraphTensor, edge_set_name: str, *,
                               feature_name: str | None = None,
                               feature_value=None):
    value = _resolve_feature(graph.context, feature_name, feature_value)
    comp = graph.edge_sets[edge_set_name].component_ids()
    return jnp.take(value, jnp.minimum(comp, value.shape[0] - 1), axis=0)


def pool_nodes_to_context(graph: GraphTensor, node_set_name: str,
                          reduce_type: str = "sum", *,
                          feature_name: str | None = None,
                          feature_value=None):
    """Aggregate node values per graph component."""
    ns = graph.node_sets[node_set_name]
    value = _resolve_feature(ns, feature_name, feature_value)
    comp = ns.component_ids()
    c = graph.num_components
    mask = ns.mask()
    mask_b = mask.reshape(mask.shape + (1,) * (value.ndim - 1))
    comp = jnp.where(mask, comp, c)  # padding -> overflow bucket
    if reduce_type in ("sum", "mean"):
        pooled = jax.ops.segment_sum(jnp.where(mask_b, value, 0), comp,
                                     num_segments=c + 1)[:c]
        if reduce_type == "mean":
            cnt = jax.ops.segment_sum(mask.astype(value.dtype), comp,
                                      num_segments=c + 1)[:c]
            shape = cnt.shape + (1,) * (value.ndim - 1)
            pooled = pooled / jnp.maximum(cnt, 1).reshape(shape)
        return pooled
    if reduce_type in ("max", "min"):
        neutral = _NEUTRAL[reduce_type]
        fn = _SEGMENT_REDUCERS[reduce_type]
        pooled = fn(jnp.where(mask_b, value, neutral), comp,
                    num_segments=c + 1)[:c]
        return jnp.where(jnp.isfinite(pooled), pooled, 0)
    raise ValueError(reduce_type)


def pool_edges_to_context(graph: GraphTensor, edge_set_name: str,
                          reduce_type: str = "sum", *,
                          feature_name: str | None = None,
                          feature_value=None):
    es = graph.edge_sets[edge_set_name]
    value = _resolve_feature(es, feature_name, feature_value)
    comp = es.component_ids()
    c = graph.num_components
    mask = es.mask()
    mask_b = mask.reshape(mask.shape + (1,) * (value.ndim - 1))
    comp = jnp.where(mask, comp, c)
    if reduce_type in ("sum", "mean"):
        pooled = jax.ops.segment_sum(jnp.where(mask_b, value, 0), comp,
                                     num_segments=c + 1)[:c]
        if reduce_type == "mean":
            cnt = jax.ops.segment_sum(mask.astype(value.dtype), comp,
                                      num_segments=c + 1)[:c]
            shape = cnt.shape + (1,) * (value.ndim - 1)
            pooled = pooled / jnp.maximum(cnt, 1).reshape(shape)
        return pooled
    neutral = _NEUTRAL[reduce_type]
    fn = _SEGMENT_REDUCERS[reduce_type]
    pooled = fn(jnp.where(mask_b, value, neutral), comp,
                num_segments=c + 1)[:c]
    return jnp.where(jnp.isfinite(pooled), pooled, 0)


def node_degree(graph: GraphTensor, edge_set_name: str, tag: str):
    """Valid-edge degree of each node at endpoint `tag`."""
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    num_nodes = graph.node_sets[node_set_name].capacity
    return jax.ops.segment_sum(es.mask().astype(jnp.int32), idx,
                               num_segments=num_nodes)

"""Data-exchange ops (paper §4.1, API Level 2).

Broadcast and pool between node sets, edge sets and context.  All ops work
on the fixed-capacity GraphTensor: padding items are masked out of every
reduction, so results over valid items match the ragged semantics of the
paper exactly (tested in tests/test_ops.py against a dense-adjacency
oracle).

Index-based exchange (gather/segment ops) is the paper's core design choice
vs. adjacency matmuls.  Every segment-shaped reduction below routes through
`repro.kernels.dispatch`, the single registry/eligibility layer that picks
the Pallas TPU kernel or the jnp reference per call site; enable the kernel
path via `use_kernels(True)` or the REPRO_KERNELS env var.  Padding is
expressed uniformly by remapping padded rows' segment ids to `n_segments`
(the dispatch contract: out-of-range ids are dropped, empty segments
yield 0).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import mp_context
from repro.core.graph_tensor import (CONTEXT, GraphTensor, HIDDEN_STATE,
                                     SOURCE, TARGET)
from repro.kernels import dispatch as kernel_dispatch

_REDUCE_TYPES = ("sum", "mean", "max", "min")


# ---------------------------------------------------------------------------
# Feature-dim model parallelism (driven by the MeshPlan of
# repro.distributed.partition through repro.core.mp_context).
#
# Inside a model-parallel shard_map body the segment reductions at the
# broadcast/pool exchange boundary split the trailing feature axis over
# the "model" mesh axis: the reduction runs on this device's feature
# chunk (so kernel dispatch budgets VMEM from the per-shard width) and
# the pooled result is all-gathered back to full width — the one
# cross-device exchange of the model-parallel contract.  Broadcast
# (`jnp.take`) needs no collective: its input is already full width
# (gathered at step entry / at the previous pool exit) and a gather of a
# replicated value is communication-free.
#
# Chunks are exact slices, reductions are feature-independent and the
# gather concatenates them in mesh order, so results are bit-identical to
# the unsharded path at any model_parallel factor.  Widths the model axis
# does not divide fall back to the unsharded op.
# ---------------------------------------------------------------------------

def _mp_segment_reduce(value, seg_ids, n_segments, reduce_type,
                       sorted_ids=None):
    """Segment reduction with the feature axis split over the model mesh
    axis (all-gather at the pool boundary); unsharded outside a
    model-parallel trace context.  sorted_ids is the layout hint for
    dispatch (None defers to the ambient `dispatch.layout()` context;
    performance-only, never correctness)."""
    ctx = mp_context.current_model_context()
    if ctx is not None and ctx.can_split(value):
        out = kernel_dispatch.segment_reduce(ctx.split(value), seg_ids,
                                             n_segments, reduce_type,
                                             sorted_ids=sorted_ids)
        return ctx.gather(out)
    return kernel_dispatch.segment_reduce(value, seg_ids, n_segments,
                                          reduce_type,
                                          sorted_ids=sorted_ids)


def use_kernels(enabled: bool) -> None:
    kernel_dispatch.enable(enabled)


def kernels_enabled() -> bool:
    return kernel_dispatch.enabled()


def _edge_endpoint(graph: GraphTensor, edge_set_name: str, tag: str):
    es = graph.edge_sets[edge_set_name]
    adj = es.adjacency
    if tag == SOURCE:
        return adj.source, adj.source_name
    if tag == TARGET:
        return adj.target, adj.target_name
    raise ValueError(f"tag must be SOURCE or TARGET, got {tag!r}")


def _resolve_feature(piece, feature_name, feature_value):
    if (feature_name is None) == (feature_value is None):
        raise ValueError("exactly one of feature_name/feature_value required")
    return piece[feature_name] if feature_name is not None else feature_value


# ---------------------------------------------------------------------------
# node <-> edge
# ---------------------------------------------------------------------------

def broadcast_node_to_edges(graph: GraphTensor, edge_set_name: str, tag: str,
                            *, feature_name: str | None = None,
                            feature_value=None):
    """For each edge, the feature value at its `tag` endpoint node."""
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    value = _resolve_feature(graph.node_sets[node_set_name], feature_name,
                             feature_value)
    return jnp.take(value, idx, axis=0)


def pool_edges_to_node(graph: GraphTensor, edge_set_name: str, tag: str,
                       reduce_type: str = "sum", *,
                       feature_name: str | None = None, feature_value=None):
    """Aggregate per-edge values at each `tag` endpoint node (paper Eq. 3).

    Padding edges are excluded; nodes with no (valid) incident edges
    yield 0 for every reduce_type.
    """
    if reduce_type not in _REDUCE_TYPES:
        raise ValueError(f"unknown reduce_type {reduce_type!r}")
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    value = _resolve_feature(es, feature_name, feature_value)
    num_nodes = graph.node_sets[node_set_name].capacity
    seg_ids = jnp.where(es.mask(), idx, num_nodes)  # padding -> dropped
    # BatchPlan sorts edges by (component, target) and pads last, so
    # TARGET-keyed ids are non-decreasing exactly when the ambient
    # dispatch.layout() hint says so; SOURCE-keyed ids never are.
    return _mp_segment_reduce(value, seg_ids, num_nodes, reduce_type,
                              sorted_ids=None if tag == TARGET else False)


def segment_softmax(graph: GraphTensor, edge_set_name: str, tag: str,
                    *, feature_value):
    """Softmax of per-edge scores within each receiver node's edge segment
    (the attention-pooling primitive used by GATv2/transformer convs)."""
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    num_nodes = graph.node_sets[node_set_name].capacity
    emask = es.mask()
    emask_b = emask.reshape(emask.shape + (1,) * (feature_value.ndim - 1))
    seg_ids = jnp.where(emask, idx, num_nodes)
    sorted_ids = None if tag == TARGET else False
    # max-shift for stability, then exp-sum — both dispatched reductions
    # (feature-split over the model axis inside a model-parallel trace)
    seg_max = _mp_segment_reduce(feature_value, seg_ids, num_nodes, "max",
                                 sorted_ids=sorted_ids)
    shifted = jnp.where(emask_b,
                        feature_value - jnp.take(seg_max, idx, axis=0),
                        -jnp.inf)
    exp = jnp.where(emask_b, jnp.exp(shifted), 0)
    seg_sum = _mp_segment_reduce(exp, seg_ids, num_nodes, "sum",
                                 sorted_ids=sorted_ids)
    denom = jnp.take(seg_sum, idx, axis=0)
    return exp / jnp.maximum(denom, 1e-37)


# ---------------------------------------------------------------------------
# context <-> node/edge
# ---------------------------------------------------------------------------

def _piece(graph: GraphTensor, name: str, node_or_edge: str):
    return (graph.node_sets[name] if node_or_edge == "node"
            else graph.edge_sets[name])


def broadcast_context_to_nodes(graph: GraphTensor, node_set_name: str, *,
                               feature_name: str | None = None,
                               feature_value=None):
    value = _resolve_feature(graph.context, feature_name, feature_value)
    comp = graph.node_sets[node_set_name].component_ids()
    return jnp.take(value, jnp.minimum(comp, value.shape[0] - 1), axis=0)


def broadcast_context_to_edges(graph: GraphTensor, edge_set_name: str, *,
                               feature_name: str | None = None,
                               feature_value=None):
    value = _resolve_feature(graph.context, feature_name, feature_value)
    comp = graph.edge_sets[edge_set_name].component_ids()
    return jnp.take(value, jnp.minimum(comp, value.shape[0] - 1), axis=0)


def _pool_items_to_context(piece, num_components, reduce_type, value):
    if reduce_type not in _REDUCE_TYPES:
        raise ValueError(f"unknown reduce_type {reduce_type!r}")
    comp = jnp.where(piece.mask(), piece.component_ids(),
                     num_components)  # padding -> dropped
    # component_ids is non-decreasing by construction (searchsorted over
    # the cumulative sizes) and padding rows map to num_components at the
    # end, so context pooling is always run-sorted
    return _mp_segment_reduce(value, comp, num_components, reduce_type,
                              sorted_ids=True)


def pool_nodes_to_context(graph: GraphTensor, node_set_name: str,
                          reduce_type: str = "sum", *,
                          feature_name: str | None = None,
                          feature_value=None):
    """Aggregate node values per graph component."""
    ns = graph.node_sets[node_set_name]
    value = _resolve_feature(ns, feature_name, feature_value)
    return _pool_items_to_context(ns, graph.num_components, reduce_type,
                                  value)


def pool_edges_to_context(graph: GraphTensor, edge_set_name: str,
                          reduce_type: str = "sum", *,
                          feature_name: str | None = None,
                          feature_value=None):
    es = graph.edge_sets[edge_set_name]
    value = _resolve_feature(es, feature_name, feature_value)
    return _pool_items_to_context(es, graph.num_components, reduce_type,
                                  value)


def node_degree(graph: GraphTensor, edge_set_name: str, tag: str):
    """Valid-edge degree of each node at endpoint `tag`."""
    es = graph.edge_sets[edge_set_name]
    idx, node_set_name = _edge_endpoint(graph, edge_set_name, tag)
    num_nodes = graph.node_sets[node_set_name].capacity
    seg_ids = jnp.where(es.mask(), idx, num_nodes)
    # int32 count: exact for any degree (fp32 would stop at 2**24)
    return kernel_dispatch.segment_count(seg_ids, num_nodes,
                                         dtype=jnp.int32)

"""Out-of-core training driver — the graph lives on DISK, not in any
training or sampling process:

  GraphStore -> write_graph -> GraphDirectory (mmap-able .npy CSR +
  feature files) -> a dial-in sampler fleet (`python -m
  repro.storage.worker`) that knows only (service address, directory
  path) -> SamplingService(backend="dial") -> runner.run.

Two runs, one assertion: the dial fleet (subprocess workers, mmap +
2-shard remote lookups, bounded-RSS gathers) must train to EXACTLY the
same loss as an in-memory thread fleet on the same plan and seeds —
batches are bit-identical, so the loss trajectory is too.  On top of
loss parity the driver asserts the out-of-core claim itself: every
worker's peak RSS (written via --rss-file) stays BELOW the total bytes
of the GraphDirectory it sampled from.

    PYTHONPATH=src python examples/out_of_core_train.py

Worker processes are spawned through a tiny relay interpreter: a child
forked from this (jax-sized) process would inherit the parent's
pre-exec CoW window in its ru_maxrss and the RSS assertion would
measure the trainer, not the worker.  They also run REPRO_NO_JAX=1 —
sampler hosts are numpy-only by contract (repro-lint PUR005), and the
env var keeps an installed jax from being imported through
repro.core.graph_tensor's guarded fallback.
"""
import argparse
import os
import subprocess
import sys
import tempfile

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--papers", type=int, default=24_000)
ap.add_argument("--feat-dim", type=int, default=1024)
ap.add_argument("--roots", type=int, default=64)
ap.add_argument("--steps", type=int, default=6)
ap.add_argument("--hidden", type=int, default=32)
ap.add_argument("--workers", type=int, default=2)
ap.add_argument("--gather-chunk-rows", type=int, default=8,
                help="bounded-RSS gather window in the dial workers")
args = ap.parse_args()

import jax

from repro.core import HIDDEN_STATE, mag_schema
from repro.core.models import vanilla_mpnn
from repro.data import (InMemorySampler, SamplingSpecBuilder,
                        find_size_constraints)
from repro.data.synthetic import synthetic_mag
from repro.nn.layers import Embedding, Linear
from repro.nn.module import Module
from repro.orchestration import RootNodeMulticlassClassification, run
from repro.sampling_service import SamplingService
from repro.storage import graph_bytes, write_graph

# 1. the graph — big enough that "peak RSS below graph bytes" means
# something (paper features dominate: papers x feat_dim x 4 bytes)
schema = mag_schema()
store, _ = synthetic_mag(n_papers=args.papers,
                         n_authors=args.papers // 4,
                         n_institutions=40, n_fields=80,
                         n_classes=8, feat_dim=args.feat_dim)

b = SamplingSpecBuilder(schema)
seed_op = b.seed("paper")
cited = seed_op.sample(6, "cites")
cited.join([seed_op]).sample(4, "written")
spec = seed_op.build()

roots = list(range(args.roots))
bs = 8
sizes = find_size_constraints(
    InMemorySampler(store, spec, seed=0).sample(roots), bs)

# 2. model + task (a small §8-style MPNN; features enter via one Linear)
dim = args.hidden
# only the edge/node sets the sampling spec reaches appear in batches
edges = {name: (es.source, es.target)
         for name, es in schema.edge_sets.items()
         if name in ("cites", "written")}
gnn = vanilla_mpnn(edges, {"paper": dim, "author": dim},
                   message_dim=dim, hidden_dim=dim, num_rounds=2)


class InitStates(Module):
    """Paper features -> hidden states; id-embedding tables for the
    feature-less node sets (the §8.1 MapFeatures analogue)."""

    def __init__(self):
        self.paper = Linear(args.feat_dim, dim)
        # only node sets the sampling spec actually reaches
        self.tables = {"author": Embedding(4096, dim)}

    def init(self, key):
        ks = jax.random.split(key, 1 + len(self.tables))
        p = {"paper": self.paper.init(ks[0])}
        for i, (n, t) in enumerate(sorted(self.tables.items())):
            p[n] = t.init(ks[i + 1])
        return p

    def __call__(self, params, graph):
        ns = {"paper": {HIDDEN_STATE: jax.nn.relu(self.paper(
            params["paper"], graph.node_sets["paper"]["feat"]))}}
        for n, t in self.tables.items():
            ids = graph.node_sets[n]["id"] % 4096
            ns[n] = {HIDDEN_STATE: t(params[n], ids,
                                     dtype=jax.numpy.float32)}
        return graph.replace_features(node_sets=ns)


task = RootNodeMulticlassClassification("paper", 8, dim)


def root_labels(graph):
    """Per-group root labels [R, C] from a stacked super-batch."""
    arr = np.asarray(graph.node_sets["paper"].sizes)       # [R, C]
    lab = np.asarray(graph.node_sets["paper"]["labels"])   # [R, cap]
    return np.stack([
        RootNodeMulticlassClassification.root_labels(arr[r], lab[r])
        for r in range(arr.shape[0])
    ]).astype(np.int32)


run_kwargs = dict(model_fn=lambda: (InitStates(), gnn), task=task,
                  epochs=2, learning_rate=3e-3, total_steps=100,
                  ckpt_dir="", log_every=4, max_steps=args.steps,
                  num_devices=1, sampler="service", label_fn=root_labels)


def train_with(svc):
    return run(service=svc, **run_kwargs)


# 3. run A — in-memory thread fleet (the reference)
with SamplingService(store, spec, roots, batch_size=bs, sizes=sizes,
                     num_workers=args.workers, num_replicas=1, seed=0,
                     base_seed=0, backend="thread") as svc:
    ref = train_with(svc)
print(f"in-memory fleet: loss {ref.train_loss:.6f} "
      f"({ref.step} steps)", flush=True)

# 4. run B — the SAME training stream from an out-of-core dial fleet
with tempfile.TemporaryDirectory(prefix="out_of_core_") as tmp:
    gdir = write_graph(store, os.path.join(tmp, "graph"))
    total = graph_bytes(gdir)
    print(f"GraphDirectory: {total / 2**20:.0f} MB at {gdir}", flush=True)

    procs, rss_files = [], []
    # fork+exec from a small relay so each worker's ru_maxrss starts at
    # a bare interpreter, not this process's CoW window
    relay = "import subprocess, sys; sys.exit(subprocess.call(sys.argv[1:]))"
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ, REPRO_NO_JAX="1",
               PYTHONPATH=src_root + os.pathsep +
               os.environ.get("PYTHONPATH", ""))

    def spawn_workers(address):
        host, port = address
        for w in range(args.workers):
            rss = os.path.join(tmp, f"worker{w}.rss")
            rss_files.append(rss)
            procs.append(subprocess.Popen(
                [sys.executable, "-c", relay,
                 sys.executable, "-m", "repro.storage.worker",
                 "--connect", f"{host}:{port}", "--graph-dir", gdir,
                 "--gather-chunk-rows", str(args.gather_chunk_rows),
                 "--rss-file", rss], env=env))

    svc = SamplingService(None, spec, roots, batch_size=bs, sizes=sizes,
                          num_workers=args.workers, num_replicas=1,
                          seed=0, base_seed=0, backend="dial",
                          num_shards=args.workers, accept_timeout=120.0,
                          on_listen=spawn_workers)
    try:
        got = train_with(svc)
    finally:
        svc.close()
        for p in procs:
            p.wait(30.0)

    print(f"dial fleet:      loss {got.train_loss:.6f} "
          f"({got.step} steps)", flush=True)
    assert got.step == ref.step
    assert got.train_loss == ref.train_loss, (
        f"out-of-core loss {got.train_loss!r} != "
        f"in-memory loss {ref.train_loss!r}")

    for w, rss_file in enumerate(rss_files):
        with open(rss_file) as f:
            peak = int(f.read())
        ratio = peak / total
        print(f"worker {w}: peak RSS {peak / 2**20:.0f} MB / "
              f"graph {total / 2**20:.0f} MB (ratio {ratio:.2f})",
              flush=True)
        assert peak < total, (
            f"worker {w} peak RSS {peak} >= graph bytes {total} — "
            "not out-of-core")

print("out_of_core_train OK")

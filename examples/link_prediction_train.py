"""Link prediction on a heterogeneous edge set through the orchestration
layer — and the sample-on-demand `StoreProvider`:

  synthetic MAG store -> SamplingSpec (paper/cites/written/writes) ->
  StoreProvider (Algorithm 1 per step, no pre-sampled corpus; the same
  provider fronts an out-of-core `MmapGraphStore`) -> 2-round hetero MPNN
  -> LinkPrediction("writes"): bilinear author->paper pair scores with
  seeded per-component negative sampling -> Trainer.

Negatives are drawn host-side from `seed_rng(base_seed, (epoch, step))`,
so the stream — and therefore the loss — is invariant to sampler fleet
size and shard count (property-tested in tests/test_task_property.py).

    PYTHONPATH=src python examples/link_prediction_train.py

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/link_prediction_train.py --steps 3 \\
        --num-devices 8 --expect-loss <pinned>

``--expect-loss`` turns the run into a 4-decimal regression gate (the CI
smoke pin).
"""
import argparse

import jax
import numpy as np

from repro.core import HIDDEN_STATE, mag_schema
from repro.core.models import vanilla_mpnn
from repro.data import (SamplingSpecBuilder, find_size_constraints,
                        sample_subgraph)
from repro.data.sampling import seed_rng
from repro.data.synthetic import synthetic_mag
from repro.nn.layers import Embedding, Linear
from repro.nn.module import Module
from repro.orchestration import LinkPrediction, StoreProvider, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--papers", type=int, default=480)
ap.add_argument("--epochs", type=int, default=3)
ap.add_argument("--hidden", type=int, default=32)
ap.add_argument("--rounds", type=int, default=2)
ap.add_argument("--negatives", type=int, default=4)
ap.add_argument("--steps", type=int, default=None,
                help="cap total train steps (smoke runs use --steps 3)")
ap.add_argument("--num-devices", type=int, default=1)
ap.add_argument("--expect-loss", type=float, default=None,
                help="assert the final train loss equals this to 4 "
                     "decimals (CI smoke pin)")
args = ap.parse_args()

schema = mag_schema()
store, _ = synthetic_mag(n_papers=args.papers,
                         n_authors=args.papers // 2, n_institutions=40,
                         n_fields=80, n_classes=8, feat_dim=32)

# sampling spec: seed papers, their citations, the authorship
# neighborhood — "writes" (author -> paper) is the heterogeneous edge set
# the task scores
b = SamplingSpecBuilder(schema)
seed_op = b.seed("paper")
cited = seed_op.sample(8, "cites")
authors = cited.join([seed_op]).sample(4, "written")
authors.sample(4, "writes")
spec = seed_op.build()

roots = np.arange(args.papers)
n_train = int(args.papers * 0.75)
train_roots, val_roots = roots[:n_train], roots[n_train:]

bs = 16
ndev = args.num_devices
if bs % ndev:
    raise SystemExit(f"devices {ndev} must divide batch size {bs}")
# profiling pass for the static padding capacities (the provider itself
# samples on demand — no pre-sampled corpus is retained)
profile = [sample_subgraph(store, spec, int(r), seed_rng(0, int(r)))
           for r in roots]
sizes = find_size_constraints(profile, bs // ndev)
del profile

train_provider = StoreProvider(store, spec, train_roots, batch_size=bs,
                               sizes=sizes, seed=0, num_replicas=ndev,
                               base_seed=0)
val_provider = StoreProvider(store, spec, val_roots, batch_size=bs,
                             sizes=sizes, seed=0, num_replicas=ndev,
                             base_seed=0)

dim = args.hidden
edges = {"cites": ("paper", "paper"), "written": ("paper", "author"),
         "writes": ("author", "paper")}


class InitStates(Module):
    """MapFeatures analogue: paper features + author id-embeddings."""

    def __init__(self):
        self.paper = Linear(32, dim)
        self.author = Embedding(4096, dim)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"paper": self.paper.init(k1),
                "author": self.author.init(k2)}

    def __call__(self, params, graph):
        ids = graph.node_sets["author"]["id"] % 4096
        return graph.replace_features(node_sets={
            "paper": {HIDDEN_STATE: jax.nn.relu(self.paper(
                params["paper"], graph.node_sets["paper"]["feat"]))},
            "author": {HIDDEN_STATE: self.author(
                params["author"], ids, dtype=jax.numpy.float32)},
        })


gnn = vanilla_mpnn(edges, {"paper": dim, "author": dim}, message_dim=dim,
                   hidden_dim=dim, num_rounds=args.rounds,
                   use_layer_norm=True)
task = LinkPrediction("writes", dim, num_negatives=args.negatives,
                      base_seed=0)

trainer = Trainer(epochs=args.epochs, learning_rate=3e-3,
                  total_steps=300, num_devices=ndev,
                  max_steps=args.steps, log_every=20, eval_at="end")
result = trainer.fit(lambda: (InitStates(), gnn), task, train_provider,
                     eval_provider=val_provider)

em = result.metrics["eval"]
print(f"final loss {result.train_loss:.4f}  "
      f"eval accuracy {em['accuracy']:.4f}  eval loss {em['loss']:.4f}  "
      f"({ndev} device(s), {result.step} steps)")
if args.expect_loss is not None:
    assert abs(result.train_loss - args.expect_loss) < 5e-5, \
        f"loss {result.train_loss:.6f} != pinned {args.expect_loss:.4f}"
if args.steps is None:  # full runs gate on ranking accuracy
    assert em["accuracy"] > 0.7, em
print("link_prediction_train OK")

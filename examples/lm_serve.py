"""Serve a small LM with batched requests through the continuous-batching
engine (prefill + fused decode ticks + slot recycling).

    PYTHONPATH=src python examples/lm_serve.py --arch rwkv6-3b-smoke
"""
import argparse
import time

import jax
import numpy as np

from repro.models.registry import build_model, get_config
from repro.nn.module import split_params
from repro.serve.engine import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen1.5-4b-smoke")
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--new-tokens", type=int, default=16)
args = ap.parse_args()

cfg = get_config(args.arch)
model = build_model(cfg)
params, _ = split_params(model.init(jax.random.PRNGKey(0)))
engine = ServeEngine(cfg, params, n_slots=4, max_len=128)

rng = np.random.default_rng(0)
prompt_len = 12
reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, prompt_len)
                .astype(np.int32),
                max_new_tokens=args.new_tokens,
                temperature=0.0 if i % 2 == 0 else 0.8)
        for i in range(args.requests)]

t0 = time.time()
done = engine.run(reqs)
dt = time.time() - t0
total_new = sum(len(r.generated) for r in done)
print(f"served {len(done)} requests, {total_new} tokens "
      f"in {dt:.2f}s ({total_new / dt:.1f} tok/s on 1 CPU)")
for i, r in enumerate(done[:3]):
    print(f"req{i}: prompt={r.prompt[:6].tolist()}... "
          f"generated={r.generated[:8]}...")
assert all(r.done for r in done)
assert all(len(r.generated) >= args.new_tokens for r in done)
print("lm_serve OK")

"""End-to-end driver — the paper's §8 OGBN-MAG case study, soup to nuts:

  schema -> SamplingSpecBuilder (Fig. 6) -> distributed sampler (Alg. 1,
  persisted shards) -> GraphBatcher (merge+pad) -> 4-round MPNN (Fig. 7/8)
  -> RootNodeMulticlassClassification -> runner.run with checkpointing.

Uses the synthetic-MAG generator (OGB download unavailable offline); the
planted signal makes neighborhood aggregation necessary, so the experiment
is qualitatively faithful to Table 1.

    PYTHONPATH=src python examples/ogbn_mag_train.py

Data-parallel over N (possibly host-forced) devices — the batch becomes a
super-batch of padded component groups sharded over the mesh's "data"
axis; loss matches the 1-device run on the same seed:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --steps 3 --num-devices 8

``--model-parallel M`` folds the mesh to 2-D (data = N/M rows x model = M
columns): node/edge feature dims shard over "model" (all-gathered exactly
at the broadcast/pool boundary of repro.core.ops) and AdamW state is
ZeRO-1-sharded over "data" — same loss again, with per-device optimizer
state shrunk by the data factor:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --steps 3 --num-devices 8 \\
        --model-parallel 2

With ``--sampler service`` the training stream comes from the async
sampling service instead (repro.sampling_service): a fleet of sampler
worker processes runs Algorithm 1 + merge + pad off the training host
path and streams padded super-batches over length-prefixed socket frames,
double-buffered onto the mesh.  Same plan, same per-root sampling seeds
=> bit-identical batches => the same loss as the in-process path:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --sampler service --num-devices 8

``--multihost N`` crosses the process boundary: the script relaunches
itself as N `jax.distributed` processes (each contributing
``num_devices / N`` local devices to one GLOBAL mesh), process 0
additionally hosts a `SamplerEndpoint` whose per-rank `SamplingService`
fleets stream every rank's batches over TCP (`RemoteStreamClient` with
reconnect + resume-from-watermark), and the per-process rank shards are
assembled into global super-batches.  Same plan, same seeds, same global
mesh => the same loss as the single-process run of the same size:

    PYTHONPATH=src python examples/ogbn_mag_train.py --steps 3 \\
        --num-devices 4 --multihost 2

Per-rank logs land in ``--multihost-log-dir`` (the CI smoke job uploads
them as artifacts).  Ports are OS-assigned; the coordinator address and
the endpoint address travel to the children via environment / a shared
address file, never fixed port numbers.
"""
import argparse
import os
import socket
import subprocess
import sys
import tempfile
import time

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--papers", type=int, default=1200)
ap.add_argument("--epochs", type=int, default=4)
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--steps", type=int, default=None,
                help="cap total train steps (smoke runs use --steps 3)")
ap.add_argument("--num-devices", type=int, default=1,
                help="total (GLOBAL) mesh devices; >1 needs that many "
                     "devices "
                     "(XLA_FLAGS=--xla_force_host_platform_device_count=N; "
                     "with --multihost N the launcher forces "
                     "num_devices/N per process)")
ap.add_argument("--model-parallel", type=int, default=1,
                help="model columns of the 2-D mesh (must divide "
                     "--num-devices); feature dims shard over 'model', "
                     "optimizer state ZeRO-1-shards over 'data'")
ap.add_argument("--sampler", choices=["inprocess", "service"],
                default="inprocess",
                help="'service' streams training batches from the async "
                     "sampler fleet (identical loss, sampling off the "
                     "trainer host path)")
ap.add_argument("--sampler-workers", type=int, default=2,
                help="sampler fleet size for --sampler service")
ap.add_argument("--multihost", type=int, default=0, metavar="N",
                help="launch N jax.distributed processes sharing one "
                     "global mesh of --num-devices devices; sampler "
                     "batches stream from a rank-0 SamplerEndpoint over "
                     "TCP.  Reaches the same loss as the 1-process run "
                     "of the same --num-devices")
ap.add_argument("--multihost-log-dir", default="",
                help="directory for per-rank log files (default: a temp "
                     "dir, printed at launch)")
ap.add_argument("--multihost-timeout", type=float, default=900.0,
                help="launcher kills the fleet after this many seconds")
args = ap.parse_args()


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _launch_multihost(args) -> int:
    """Parent mode: spawn --multihost N child processes of this very
    command line (children are marked by REPRO_PROCESS_ID), harvest
    their per-rank logs, and propagate failure.  Never imports jax."""
    nproc = args.multihost
    if args.num_devices % nproc:
        raise SystemExit(f"--multihost {nproc} must divide "
                         f"--num-devices {args.num_devices}")
    local_dev = args.num_devices // nproc
    coord = f"127.0.0.1:{_free_port()}"
    tmp = tempfile.mkdtemp(prefix="ogbn_multihost_")
    endpoint_file = os.path.join(tmp, "endpoint_addr")
    log_dir = args.multihost_log_dir or os.path.join(tmp, "logs")
    os.makedirs(log_dir, exist_ok=True)
    print(f"multihost: {nproc} processes x {local_dev} devices, "
          f"coordinator {coord}, logs in {log_dir}", flush=True)
    procs = []
    for r in range(nproc):
        env = dict(os.environ,
                   REPRO_COORDINATOR=coord,
                   REPRO_NUM_PROCESSES=str(nproc),
                   REPRO_PROCESS_ID=str(r),
                   REPRO_ENDPOINT_FILE=endpoint_file,
                   XLA_FLAGS="--xla_force_host_platform_device_count="
                             f"{local_dev}")
        log = open(os.path.join(log_dir, f"rank{r}.log"), "wb")
        procs.append((r, subprocess.Popen(
            [sys.executable] + sys.argv, env=env,
            stdout=log, stderr=subprocess.STDOUT), log))
    deadline = time.monotonic() + args.multihost_timeout
    status = 0
    for r, p, log in procs:
        try:
            code = p.wait(max(deadline - time.monotonic(), 0.1))
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait()
            code = -9
            print(f"rank {r}: TIMEOUT after "
                  f"{args.multihost_timeout:.0f}s — killed", flush=True)
        log.close()
        if code != 0:
            status = 1
        with open(os.path.join(log_dir, f"rank{r}.log"), "rb") as f:
            tail = f.read()[-2000:].decode(errors="replace")
        print(f"--- rank {r} exit {code}; log tail ---\n{tail}",
              flush=True)
    for _, p, _ in procs:  # a straggler past a peer's failure
        if p.poll() is None:
            p.kill()
            p.wait()
    print("multihost:", "OK" if status == 0 else "FAILED", flush=True)
    return status


if args.multihost > 1 and "REPRO_PROCESS_ID" not in os.environ:
    raise SystemExit(_launch_multihost(args))

import jax

from repro.core import HIDDEN_STATE, mag_schema
from repro.core.models import vanilla_mpnn
from repro.data import (GraphBatcher, SamplingSpecBuilder,
                        distributed_sample, find_size_constraints,
                        load_graphs, shard_partition)
from repro.data.synthetic import synthetic_mag
from repro.distributed.partition import initialize_distributed
from repro.nn.layers import Embedding, Linear
from repro.nn.module import Module
from repro.orchestration import RootNodeMulticlassClassification, run
from repro.sampling_service import (RemoteStreamClient, SamplerEndpoint,
                                    SamplingService)

# joins the jax.distributed job when the --multihost launcher (or an
# external orchestrator) exported REPRO_COORDINATOR/..; no-op otherwise.
# Must run before the first jax computation.
initialize_distributed()
rank = jax.process_index()
world = jax.process_count()

# 1. problem identification + schema (paper §8.1)
schema = mag_schema()
store, labels = synthetic_mag(n_papers=args.papers,
                              n_authors=args.papers // 2,
                              n_institutions=40, n_fields=80,
                              n_classes=8, feat_dim=32)

# 2. sampling spec (paper Fig. 6) + distributed sampling (§8.2)
b = SamplingSpecBuilder(schema)
seed_op = b.seed("paper")
cited = seed_op.sample(8, "cites")
authors = cited.join([seed_op]).sample(4, "written")
author_papers = authors.sample(4, "writes")
authors.sample(4, "affiliated_with")
author_papers.join([seed_op, cited]).sample(4, "has_topic")
spec = seed_op.build()
print("sampling ops:", [op.op_name for op in spec.sampling_ops])

num_shards = 4
with tempfile.TemporaryDirectory() as tmp:
    n_train = int(args.papers * 0.75)
    shards = distributed_sample(store, spec, range(args.papers), tmp,
                                num_shards=num_shards)
    graphs = [g for p in shards for g in load_graphs(p)]
# roots in shard-file order — graphs[i] is the subgraph rooted at
# root_order[i], sampled with seed_rng(0, root); the sampling service
# reproduces graphs bit-identically from these roots
root_order = np.concatenate(shard_partition(range(args.papers), num_shards))
print(f"sampled {len(graphs)} rooted subgraphs via "
      f"{num_shards} shard workers")
train_graphs = graphs[:n_train]
train_roots = root_order[:n_train]
test_graphs = graphs[n_train:]

# 3. modeling (paper §8.3: 4-round MPNN over all five edge sets)
dim = args.hidden
edges = {name: (es.source, es.target)
         for name, es in schema.edge_sets.items()}
node_dims = {n: dim for n in schema.node_sets}


class InitStates(Module):
    """MapFeatures analogue: paper features -> uniform hidden states;
    id-embedding tables for institutions/fields (paper §8.1)."""

    def __init__(self):
        self.paper = Linear(32, dim)
        self.tables = {n: Embedding(4096, dim)
                       for n in ("author", "institution", "field_of_study")}

    def init(self, key):
        ks = jax.random.split(key, 4)
        p = {"paper": self.paper.init(ks[0])}
        for i, (n, t) in enumerate(sorted(self.tables.items())):
            p[n] = t.init(ks[i + 1])
        return p

    def __call__(self, params, graph):
        ns = {"paper": {HIDDEN_STATE: jax.nn.relu(self.paper(
            params["paper"], graph.node_sets["paper"]["feat"]))}}
        for n, t in self.tables.items():
            ids = graph.node_sets[n]["id"] % 4096
            ns[n] = {HIDDEN_STATE: t(params[n], ids,
                                     dtype=jax.numpy.float32)}
        return graph.replace_features(node_sets=ns)


gnn = vanilla_mpnn(edges, node_dims, message_dim=dim, hidden_dim=dim,
                   num_rounds=4, use_layer_norm=True)

# 4. orchestration (paper §8.4) — the batch is a super-batch of one
# padded component group per DATA shard (= num_devices / model_parallel);
# SizeConstraints are per group, so the same seed trains to the same loss
# at any device count — and at any process count: each jax.distributed
# rank produces its GraphBatcher(rank, world) shard of the same global
# groups, reassembled onto the same global mesh rows.
bs = 16
ndev = args.num_devices
mp = args.model_parallel
if ndev % mp:
    raise SystemExit(f"--model-parallel {mp} must divide "
                     f"--num-devices {ndev}")
rep = ndev // mp  # GLOBAL data shards = component groups per super-batch
if bs % rep:
    raise SystemExit(f"data shards {rep} must divide batch size {bs}")
if rep % world:
    raise SystemExit(f"processes {world} must divide data shards {rep}")
rep_local = rep // world  # this process's component groups per step
sizes = find_size_constraints(graphs, bs // rep)
task = RootNodeMulticlassClassification("paper", 8, dim)


def super_batch_labels(graph):
    """Per-group root labels [R, C] from a stacked super-batch."""
    root_labels = RootNodeMulticlassClassification.root_labels
    arr = np.asarray(graph.node_sets["paper"].sizes)       # [R, C]
    lab = np.asarray(graph.node_sets["paper"]["labels"])   # [R, cap]
    return np.stack([
        root_labels(arr[r], lab[r]) for r in range(arr.shape[0])
    ]).astype(np.int32)


def batches_for(gs):
    batcher = GraphBatcher(gs, bs, sizes, seed=0, rank=rank, world=world,
                           num_replicas=rep_local)

    def gen(epoch):
        for graph in batcher.epoch(epoch):
            yield graph, super_batch_labels(graph)

    return gen


def _endpoint_file() -> str:
    path = os.environ.get("REPRO_ENDPOINT_FILE", "")
    if not path:
        raise SystemExit(
            "multi-process run without REPRO_ENDPOINT_FILE: use "
            "--multihost N (or export the REPRO_* env the launcher sets)")
    return path


def _publish_endpoint(address) -> None:
    """Atomically write the endpoint's (host, port) for the other ranks
    (OS-assigned port: nothing is known before the listener binds)."""
    path = _endpoint_file()
    tmp_path = path + ".tmp"
    with open(tmp_path, "w") as f:
        f.write(f"{address[0]}:{address[1]}")
    os.replace(tmp_path, path)


def _read_endpoint(timeout: float = 120.0):
    path = _endpoint_file()
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                host, port = f.read().strip().rsplit(":", 1)
                return host, int(port)
        except (FileNotFoundError, ValueError):
            time.sleep(0.05)
    raise SystemExit(f"rank {rank}: no endpoint address in {path} "
                     f"after {timeout:.0f}s")


run_kwargs = dict(model_fn=lambda: (InitStates(), gnn), task=task,
                  epochs=args.epochs, learning_rate=3e-3, total_steps=600,
                  eval_batches=lambda: batches_for(test_graphs)(0),
                  ckpt_dir="", log_every=20, num_devices=ndev,
                  model_parallel=mp, max_steps=args.steps)
sampler_kind = args.sampler
if world > 1:
    # multi-host: rank 0 hosts the sampler fleets behind a TCP endpoint;
    # every rank (rank 0 included) consumes its own stream through a
    # RemoteStreamClient — batches identical to the in-process
    # GraphBatcher(rank, world) stream, delivered over TCP.
    sampler_kind = "service/tcp"
    endpoint = None
    if rank == 0:
        def rank_fleet(r):
            return SamplingService(store, spec, train_roots, batch_size=bs,
                                   sizes=sizes,
                                   num_workers=args.sampler_workers,
                                   num_replicas=rep_local, seed=0, rank=r,
                                   world=world, base_seed=0)
        endpoint = SamplerEndpoint(rank_fleet)
        _publish_endpoint(endpoint.address)
    client = RemoteStreamClient(_read_endpoint(), rank)
    try:
        result = run(sampler="service", service=client,
                     label_fn=super_batch_labels, **run_kwargs)
    finally:
        client.close()
        if endpoint is not None:
            endpoint.close()
elif args.sampler == "service":
    # same plan (batch_size/seed/num_replicas) + same per-root sampling
    # seeds as the in-process path => bit-identical batches, same loss —
    # but Algorithm 1 + merge + pad run in the worker fleet, not here
    with SamplingService(store, spec, train_roots, batch_size=bs,
                         sizes=sizes, num_workers=args.sampler_workers,
                         num_replicas=rep, seed=0, base_seed=0) as svc:
        result = run(sampler="service", service=svc,
                     label_fn=super_batch_labels, **run_kwargs)
else:
    result = run(train_batches=batches_for(train_graphs), **run_kwargs)
if rank == 0:
    print(f"final loss {result.train_loss:.4f}  "
          f"test accuracy {result.metrics['eval_accuracy']:.4f}  "
          f"({ndev} device(s) = {rep} data x {mp} model over {world} "
          f"process(es), {result.step} steps, {sampler_kind} sampler)")
else:
    print(f"rank {rank}/{world} loss {result.train_loss:.4f} "
          f"({result.step} steps)")
if args.steps is None:  # full runs keep the accuracy gate; --steps N
    assert result.metrics["eval_accuracy"] > 0.5  # smoke runs skip it
print("ogbn_mag_train OK")

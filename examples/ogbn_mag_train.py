"""End-to-end driver — the paper's §8 OGBN-MAG case study, soup to nuts:

  schema -> SamplingSpecBuilder (Fig. 6) -> distributed sampler (Alg. 1,
  persisted shards) -> GraphBatcher (merge+pad) -> 4-round MPNN (Fig. 7/8)
  -> RootNodeMulticlassClassification -> runner.run with checkpointing.

Uses the synthetic-MAG generator (OGB download unavailable offline); the
planted signal makes neighborhood aggregation necessary, so the experiment
is qualitatively faithful to Table 1.

    PYTHONPATH=src python examples/ogbn_mag_train.py

Data-parallel over N (possibly host-forced) devices — the batch becomes a
super-batch of padded component groups sharded over the mesh's "data"
axis; loss matches the 1-device run on the same seed:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --steps 3 --num-devices 8

``--model-parallel M`` folds the mesh to 2-D (data = N/M rows x model = M
columns): node/edge feature dims shard over "model" (all-gathered exactly
at the broadcast/pool boundary of repro.core.ops) and AdamW state is
ZeRO-1-sharded over "data" — same loss again, with per-device optimizer
state shrunk by the data factor:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --steps 3 --num-devices 8 \\
        --model-parallel 2

With ``--sampler service`` the training stream comes from the async
sampling service instead (repro.sampling_service): a fleet of sampler
worker processes runs Algorithm 1 + merge + pad off the training host
path and streams padded super-batches over length-prefixed socket frames,
double-buffered onto the mesh.  Same plan, same per-root sampling seeds
=> bit-identical batches => the same loss as the in-process path:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/ogbn_mag_train.py --sampler service --num-devices 8
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.core import HIDDEN_STATE, mag_schema
from repro.core.models import vanilla_mpnn
from repro.data import (GraphBatcher, SamplingSpecBuilder,
                        distributed_sample, find_size_constraints,
                        load_graphs, shard_partition)
from repro.data.synthetic import synthetic_mag
from repro.nn.layers import Embedding, Linear
from repro.nn.module import Module
from repro.orchestration import RootNodeMulticlassClassification, run
from repro.sampling_service import SamplingService

ap = argparse.ArgumentParser()
ap.add_argument("--papers", type=int, default=1200)
ap.add_argument("--epochs", type=int, default=4)
ap.add_argument("--hidden", type=int, default=64)
ap.add_argument("--steps", type=int, default=None,
                help="cap total train steps (smoke runs use --steps 3)")
ap.add_argument("--num-devices", type=int, default=1,
                help="total mesh devices; >1 needs that many devices "
                     "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")
ap.add_argument("--model-parallel", type=int, default=1,
                help="model columns of the 2-D mesh (must divide "
                     "--num-devices); feature dims shard over 'model', "
                     "optimizer state ZeRO-1-shards over 'data'")
ap.add_argument("--sampler", choices=["inprocess", "service"],
                default="inprocess",
                help="'service' streams training batches from the async "
                     "sampler fleet (identical loss, sampling off the "
                     "trainer host path)")
ap.add_argument("--sampler-workers", type=int, default=2,
                help="sampler fleet size for --sampler service")
args = ap.parse_args()

# 1. problem identification + schema (paper §8.1)
schema = mag_schema()
store, labels = synthetic_mag(n_papers=args.papers,
                              n_authors=args.papers // 2,
                              n_institutions=40, n_fields=80,
                              n_classes=8, feat_dim=32)

# 2. sampling spec (paper Fig. 6) + distributed sampling (§8.2)
b = SamplingSpecBuilder(schema)
seed_op = b.seed("paper")
cited = seed_op.sample(8, "cites")
authors = cited.join([seed_op]).sample(4, "written")
author_papers = authors.sample(4, "writes")
authors.sample(4, "affiliated_with")
author_papers.join([seed_op, cited]).sample(4, "has_topic")
spec = seed_op.build()
print("sampling ops:", [op.op_name for op in spec.sampling_ops])

num_shards = 4
with tempfile.TemporaryDirectory() as tmp:
    n_train = int(args.papers * 0.75)
    shards = distributed_sample(store, spec, range(args.papers), tmp,
                                num_shards=num_shards)
    graphs = [g for p in shards for g in load_graphs(p)]
# roots in shard-file order — graphs[i] is the subgraph rooted at
# root_order[i], sampled with seed_rng(0, root); the sampling service
# reproduces graphs bit-identically from these roots
root_order = np.concatenate(shard_partition(range(args.papers), num_shards))
print(f"sampled {len(graphs)} rooted subgraphs via "
      f"{num_shards} shard workers")
train_graphs = graphs[:n_train]
train_roots = root_order[:n_train]
test_graphs = graphs[n_train:]

# 3. modeling (paper §8.3: 4-round MPNN over all five edge sets)
dim = args.hidden
edges = {name: (es.source, es.target)
         for name, es in schema.edge_sets.items()}
node_dims = {n: dim for n in schema.node_sets}


class InitStates(Module):
    """MapFeatures analogue: paper features -> uniform hidden states;
    id-embedding tables for institutions/fields (paper §8.1)."""

    def __init__(self):
        self.paper = Linear(32, dim)
        self.tables = {n: Embedding(4096, dim)
                       for n in ("author", "institution", "field_of_study")}

    def init(self, key):
        ks = jax.random.split(key, 4)
        p = {"paper": self.paper.init(ks[0])}
        for i, (n, t) in enumerate(sorted(self.tables.items())):
            p[n] = t.init(ks[i + 1])
        return p

    def __call__(self, params, graph):
        ns = {"paper": {HIDDEN_STATE: jax.nn.relu(self.paper(
            params["paper"], graph.node_sets["paper"]["feat"]))}}
        for n, t in self.tables.items():
            ids = graph.node_sets[n]["id"] % 4096
            ns[n] = {HIDDEN_STATE: t(params[n], ids,
                                     dtype=jax.numpy.float32)}
        return graph.replace_features(node_sets=ns)


gnn = vanilla_mpnn(edges, node_dims, message_dim=dim, hidden_dim=dim,
                   num_rounds=4, use_layer_norm=True)

# 4. orchestration (paper §8.4) — the batch is a super-batch of one
# padded component group per DATA shard (= num_devices / model_parallel);
# SizeConstraints are per group, so the same seed trains to the same loss
# at any device count.
bs = 16
ndev = args.num_devices
mp = args.model_parallel
if ndev % mp:
    raise SystemExit(f"--model-parallel {mp} must divide "
                     f"--num-devices {ndev}")
rep = ndev // mp  # data shards = component groups per super-batch
if bs % rep:
    raise SystemExit(f"data shards {rep} must divide batch size {bs}")
sizes = find_size_constraints(graphs, bs // rep)
task = RootNodeMulticlassClassification("paper", 8, dim)


def super_batch_labels(graph):
    """Per-group root labels [R, C] from a stacked super-batch."""
    root_labels = RootNodeMulticlassClassification.root_labels
    arr = np.asarray(graph.node_sets["paper"].sizes)       # [R, C]
    lab = np.asarray(graph.node_sets["paper"]["labels"])   # [R, cap]
    return np.stack([
        root_labels(arr[r], lab[r]) for r in range(arr.shape[0])
    ]).astype(np.int32)


def batches_for(gs):
    batcher = GraphBatcher(gs, bs, sizes, seed=0, num_replicas=rep)

    def gen(epoch):
        for graph in batcher.epoch(epoch):
            yield graph, super_batch_labels(graph)

    return gen


run_kwargs = dict(model_fn=lambda: (InitStates(), gnn), task=task,
                  epochs=args.epochs, learning_rate=3e-3, total_steps=600,
                  eval_batches=lambda: batches_for(test_graphs)(0),
                  ckpt_dir="", log_every=20, num_devices=ndev,
                  model_parallel=mp, max_steps=args.steps)
if args.sampler == "service":
    # same plan (batch_size/seed/num_replicas) + same per-root sampling
    # seeds as the in-process path => bit-identical batches, same loss —
    # but Algorithm 1 + merge + pad run in the worker fleet, not here
    with SamplingService(store, spec, train_roots, batch_size=bs,
                         sizes=sizes, num_workers=args.sampler_workers,
                         num_replicas=rep, seed=0, base_seed=0) as svc:
        result = run(sampler="service", service=svc,
                     label_fn=super_batch_labels, **run_kwargs)
else:
    result = run(train_batches=batches_for(train_graphs), **run_kwargs)
print(f"final loss {result.train_loss:.4f}  "
      f"test accuracy {result.metrics['eval_accuracy']:.4f}  "
      f"({ndev} device(s) = {rep} data x {mp} model, {result.step} steps, "
      f"{args.sampler} sampler)")
if args.steps is None:  # full runs keep the accuracy gate; --steps N
    assert result.metrics["eval_accuracy"] > 0.5  # smoke runs skip it
print("ogbn_mag_train OK")

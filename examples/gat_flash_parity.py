"""Flash-attention GAT parity driver (the `make smoke` gate).

Builds a padded multi-component node batch, runs GraphSelfAttention once
through the einsum reference path and once through the flash kernel
(``ops.use_kernels(True)`` routes it via kernels/dispatch), and asserts
loss AND gradient parity at fp32 tolerance.  Exits non-zero on mismatch.

    PYTHONPATH=src python examples/gat_flash_parity.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HIDDEN_STATE, ops
from repro.core.graph_tensor import (Adjacency, Context, EdgeSet,
                                     GraphTensor, NodeSet)
from repro.data.batching import (SizeConstraints, merge_graphs,
                                 pad_to_sizes)
from repro.nn.graph_attention import GraphSelfAttention
from repro.nn.module import split_params

DIM = 16


def component(seed: int, n_nodes: int) -> GraphTensor:
    rng = np.random.default_rng(seed)
    e = 2 * n_nodes
    return GraphTensor.from_pieces(
        context=Context(jnp.asarray([1], jnp.int32), {}),
        node_sets={"nodes": NodeSet(
            jnp.asarray([n_nodes], jnp.int32),
            {HIDDEN_STATE: jnp.asarray(
                rng.standard_normal((n_nodes, DIM)).astype(np.float32))},
            n_nodes)},
        edge_sets={"links": EdgeSet(
            jnp.asarray([e], jnp.int32),
            Adjacency(jnp.asarray(rng.integers(0, n_nodes, e)),
                      jnp.asarray(rng.integers(0, n_nodes, e)),
                      "nodes", "nodes"), {}, e)})


def main():
    merged = merge_graphs([component(i, n) for i, n in
                           enumerate([17, 9, 23, 30])])
    sizes = SizeConstraints(total_num_components=5,
                            total_num_nodes={"nodes": 96},
                            total_num_edges={"links": 192})
    graph = pad_to_sizes(merged, sizes)

    mod = GraphSelfAttention(num_heads=4, per_head_channels=8, in_dim=DIM)
    params = split_params(mod.init(jax.random.PRNGKey(0)))[0]
    mask = graph.node_sets["nodes"].mask()[:, None]

    def loss(p):
        out = mod(p, graph, "nodes")
        return jnp.mean(jnp.where(mask, out, 0.0) ** 2)

    ref_loss, ref_grads = jax.jit(jax.value_and_grad(loss))(params)
    ops.use_kernels(True)
    try:
        flash_loss, flash_grads = jax.jit(jax.value_and_grad(loss))(params)
        flash_loss.block_until_ready()
    finally:
        ops.use_kernels(False)

    np.testing.assert_allclose(float(flash_loss), float(ref_loss),
                               rtol=1e-5, atol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
        flash_grads, ref_grads)
    print(f"flash loss {float(flash_loss):.6f} == einsum loss "
          f"{float(ref_loss):.6f} (grads match at fp32 tol)")
    print("gat_flash_parity OK")


if __name__ == "__main__":
    main()

"""Graph-level classification through the orchestration layer proper —
no `runner.run()` kwargs, just the three protocols composed directly:

  synthetic MUTAG-shaped set -> BatcherProvider (merge+pad super-batches)
  -> stacked multi-round MPNN (GNNStack) -> GraphMulticlassClassification
  (context-pooled readout) -> Trainer with a per-epoch eval stream,
  early stopping, and best-checkpoint tracking.

    PYTHONPATH=src python examples/graph_classification_train.py

Data-parallel over N forced-CPU devices (loss matches 1 device on the
same seed, like every super-batch trainer in this repo):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
        python examples/graph_classification_train.py --steps 3 \\
        --num-devices 8 --expect-loss <pinned>

``--expect-loss`` turns the run into a 4-decimal regression gate (the CI
smoke pin).  ``--ckpt-dir`` additionally exercises best-checkpoint
retention: the best eval epoch's weights survive `keep=` GC however old.
"""
import argparse
import os

import jax
import numpy as np

from repro.core import HIDDEN_STATE
from repro.core.models import vanilla_mpnn
from repro.data import find_size_constraints
from repro.data.synthetic import synthetic_graph_classification
from repro.distributed.fault_tolerance import best_checkpoint
from repro.nn.layers import Linear
from repro.nn.module import Module
from repro.orchestration import (BatcherProvider, EarlyStopping,
                                 GraphMulticlassClassification, Trainer)

ap = argparse.ArgumentParser()
ap.add_argument("--graphs", type=int, default=480)
ap.add_argument("--classes", type=int, default=3)
ap.add_argument("--epochs", type=int, default=6)
ap.add_argument("--hidden", type=int, default=32)
ap.add_argument("--rounds", type=int, default=3)
ap.add_argument("--steps", type=int, default=None,
                help="cap total train steps (smoke runs use --steps 3)")
ap.add_argument("--num-devices", type=int, default=1)
ap.add_argument("--ckpt-dir", default="",
                help="checkpoint directory (enables best-ckpt tracking)")
ap.add_argument("--patience", type=int, default=3)
ap.add_argument("--expect-loss", type=float, default=None,
                help="assert the final train loss equals this to 4 "
                     "decimals (CI smoke pin)")
args = ap.parse_args()

FEAT_DIM = 16
dim = args.hidden
graphs = synthetic_graph_classification(
    num_graphs=args.graphs, num_classes=args.classes, feat_dim=FEAT_DIM,
    seed=0)
n_train = int(args.graphs * 0.75)
train_graphs, val_graphs = graphs[:n_train], graphs[n_train:]

bs = 16
ndev = args.num_devices
if bs % ndev:
    raise SystemExit(f"devices {ndev} must divide batch size {bs}")
sizes = find_size_constraints(graphs, bs // ndev)
train_provider = BatcherProvider(train_graphs, bs, sizes, seed=0,
                                 num_replicas=ndev)
val_provider = BatcherProvider(val_graphs, bs, sizes, seed=0,
                               num_replicas=ndev)


class InitStates(Module):
    """MapFeatures analogue: atom features -> hidden states."""

    def __init__(self):
        self.atoms = Linear(FEAT_DIM, dim)

    def init(self, key):
        return {"atoms": self.atoms.init(key)}

    def __call__(self, params, graph):
        h = jax.nn.relu(self.atoms(params["atoms"],
                                   graph.node_sets["atoms"]["feat"]))
        return graph.replace_features(
            node_sets={"atoms": {HIDDEN_STATE: h}})


# the stacked (LGNN-style) multi-layer model: `--rounds` GraphUpdate
# layers with per-round weights, composed by GNNStack inside vanilla_mpnn
gnn = vanilla_mpnn({"bonds": ("atoms", "atoms")}, {"atoms": dim},
                   message_dim=dim, hidden_dim=dim,
                   num_rounds=args.rounds, use_layer_norm=True)
task = GraphMulticlassClassification("atoms", args.classes, dim)

trainer = Trainer(
    epochs=args.epochs, learning_rate=3e-3, total_steps=400,
    num_devices=ndev, max_steps=args.steps, log_every=20,
    ckpt_dir=args.ckpt_dir, save_interval_steps=20,
    eval_at="epoch",
    early_stopping=EarlyStopping(monitor="loss", patience=args.patience,
                                 mode="min"))
result = trainer.fit(lambda: (InitStates(), gnn), task, train_provider,
                     eval_provider=val_provider)

em = result.metrics["eval"]
print(f"final loss {result.train_loss:.4f}  "
      f"val accuracy {em['accuracy']:.4f}  val loss {em['loss']:.4f}  "
      f"({ndev} device(s), {result.step} steps, "
      f"best step {result.metrics.get('best_step')})")
if args.ckpt_dir:
    best = best_checkpoint(args.ckpt_dir)
    assert best is not None and os.path.isdir(best), best
    print(f"best checkpoint: {os.path.basename(best)}")
if args.expect_loss is not None:
    assert abs(result.train_loss - args.expect_loss) < 5e-5, \
        f"loss {result.train_loss:.6f} != pinned {args.expect_loss:.4f}"
if args.steps is None:  # full runs keep the accuracy gate
    assert em["accuracy"] > 0.6, em
print("graph_classification_train OK")

"""Quickstart: the paper's recommender example (Fig. 2/3 + Appendix A.3).

Builds the heterogeneous users/items graph by hand, runs the data-exchange
ops (total spend, max-spend fractions), then one GraphUpdate round.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (HIDDEN_STATE, SOURCE, TARGET, ops)
from repro.core.graph_tensor import (Adjacency, Context, EdgeSet,
                                     GraphTensor, NodeSet)
from repro.core.convolutions import SimpleConv
from repro.core.graph_update import (GraphUpdate, NextStateFromConcat,
                                     NodeSetUpdate)
from repro.nn.module import split_params

# --- the paper's example graph (Appendix A.1) ------------------------------
graph = GraphTensor.from_pieces(
    context=Context(jnp.asarray([1], jnp.int32),
                    {"scores": jnp.asarray([[0.45, 0.98, 0.10, 0.25]])}),
    node_sets={
        "items": NodeSet(jnp.asarray([6], jnp.int32), {
            "latest_price": jnp.asarray([22.34, 27.99, 89.99, 24.99,
                                         350.00, 45.13])[:, None],
        }, 6),
        "users": NodeSet(jnp.asarray([4], jnp.int32), {
            "age": jnp.asarray([24, 32, 27, 38]),
        }, 4),
    },
    edge_sets={
        "purchased": EdgeSet(
            jnp.asarray([7], jnp.int32),
            Adjacency(jnp.asarray([0, 1, 2, 3, 4, 5, 5]),
                      jnp.asarray([1, 1, 0, 0, 2, 3, 0]),
                      "items", "users"), {}, 7),
        "is-friend": EdgeSet(
            jnp.asarray([3], jnp.int32),
            Adjacency(jnp.asarray([1, 2, 3]), jnp.asarray([0, 0, 0]),
                      "users", "users"), {}, 3),
    })

# --- Appendix A.3: total and relative user spending -------------------------
purchase_prices = ops.broadcast_node_to_edges(
    graph, "purchased", SOURCE, feature_name="latest_price")
total_user_spend = ops.pool_edges_to_node(
    graph, "purchased", TARGET, "sum", feature_value=purchase_prices)
print("total spend per user:", np.asarray(total_user_spend)[:, 0])

max_spend = ops.pool_nodes_to_context(graph, "users", "max",
                                      feature_value=total_user_spend)
frac = total_user_spend / ops.broadcast_context_to_nodes(
    graph, "users", feature_value=max_spend)
print("fraction of max spend:", np.asarray(frac)[:, 0].round(3))

# --- one message-passing round (paper Fig. 7 style) --------------------------
graph = graph.replace_features(node_sets={
    "users": {HIDDEN_STATE: jnp.concatenate(
        [total_user_spend,
         graph.node_sets["users"]["age"][:, None].astype(jnp.float32)], 1)},
    "items": {HIDDEN_STATE: graph.node_sets["items"]["latest_price"]},
})
update = GraphUpdate(node_sets={
    "users": NodeSetUpdate(
        {"purchased": SimpleConv(8, 1 + 2, receiver_tag=TARGET),
         "is-friend": SimpleConv(8, 2 + 2, receiver_tag=TARGET)},
        NextStateFromConcat(2 + 16, 16)),
})
params, _ = split_params(update.init(jax.random.PRNGKey(0)))
out = jax.jit(lambda p, g: update(p, g))(params, graph)
print("updated user states:", out.node_sets["users"][HIDDEN_STATE].shape)
print("quickstart OK")

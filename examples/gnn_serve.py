"""Low-latency GNN inference serving, end to end (§6 online regime).

Stands up a `GNNServer` over synthetic MAG — on-demand seeded subgraph
sampling, dynamic micro-batching into a warmed bucket ladder, versioned
subgraph + node-embedding caches — then drives it the three ways the
benchmark gates: synchronous queries, a closed-loop client fleet, and an
open-loop (seeded-Poisson) arrival schedule.  Finishes with the
freshness story: mutating the graph bumps the store version, stale cache
entries are evicted, and re-served queries resample.

Exits non-zero if any steady-state request triggered an XLA compile —
the serving invariant (`make smoke-serve` runs this under 8 forced CPU
devices):

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        PYTHONPATH=src python examples/gnn_serve.py
"""
import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--papers", type=int, default=600)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--requests-per-client", type=int, default=25)
    ap.add_argument("--open-loop-s", type=float, default=1.0)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args(argv)

    import jax
    from repro.core import HIDDEN_STATE, mag_schema
    from repro.core.models import vanilla_mpnn
    from repro.data import SamplingSpecBuilder
    from repro.data.synthetic import synthetic_mag
    from repro.nn.layers import Linear
    from repro.nn.module import split_params
    from repro.orchestration import RootNodeMulticlassClassification
    from repro.serve import (GNNServer, VersionedGraphStore, closed_loop,
                             open_loop, spec_size_bounds)

    print(f"devices: {len(jax.devices())} ({jax.default_backend()})")

    # -- graph + sampling spec: 2-hop citation neighbourhoods -----------
    dim, n_classes = 32, 8
    raw, _ = synthetic_mag(n_papers=args.papers,
                           n_authors=args.papers // 2,
                           n_institutions=20, n_fields=40,
                           n_classes=n_classes, feat_dim=32)
    store = VersionedGraphStore.wrap(raw)
    schema = mag_schema()
    b = SamplingSpecBuilder(schema)
    seed_op = b.seed("paper")
    seed_op.sample(8, "cites").sample(4, "cites")
    spec = seed_op.build()
    bounds = spec_size_bounds(spec, schema)
    print(f"per-request worst case: {bounds.total_num_nodes} nodes, "
          f"{bounds.total_num_edges} edges")

    # -- model: init states -> 2-round MPNN -> root-node head -----------
    init = Linear(32, dim)
    gnn = vanilla_mpnn({"cites": ("paper", "paper")}, {"paper": dim},
                       message_dim=dim, hidden_dim=dim, num_rounds=2)
    task = RootNodeMulticlassClassification("paper", n_classes, dim)
    head = task.head()
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {"init": split_params(init.init(k1))[0],
              "gnn": split_params(gnn.init(k2))[0],
              "head": split_params(head.init(k3))[0]}

    def apply_fn(p, graph):
        g = graph.replace_features(node_sets={
            "paper": {HIDDEN_STATE: jax.nn.relu(
                init(p["init"], graph.node_sets["paper"]["feat"]))}})
        g = gnn(p["gnn"], g)
        return task.predict(p["head"], g)

    # -- serve ----------------------------------------------------------
    t0 = time.perf_counter()
    server = GNNServer(store, spec, apply_fn, params, feature_dim=dim,
                       max_batch=args.max_batch, batch_window_ms=1.0)
    print(f"warmup: {time.perf_counter() - t0:.2f}s, bucket ladder "
          f"{list(server.ladder.rungs)}"
          + (" (top rung trimmed by kernel VMEM budget)"
             if server.ladder.budget_limited else ""))
    try:
        logits = server.serve_sync([1, 2, 3], timeout=30)
        print(f"serve_sync([1, 2, 3]) -> logits {logits.shape}, "
              f"argmax {np.argmax(logits, axis=-1).tolist()}")

        roots = range(min(args.papers, 400))
        rep = closed_loop(server, roots, clients=args.clients,
                          requests_per_client=args.requests_per_client,
                          seed=0)
        print(f"closed loop: {rep.summary()}")
        rep2 = open_loop(server, roots, qps=max(rep.qps * 0.5, 20.0),
                         duration_s=args.open_loop_s, seed=1)
        print(f"open loop:   {rep2.summary()}")

        # -- freshness: mutate the graph, caches invalidate -------------
        before = server.submit(5).result(30)
        assert np.allclose(before, server.submit(5).result(30))
        v0 = store.version
        store.add_edges("cites", [5], [int(args.papers - 1)])
        assert store.version == v0 + 1, "mutation must bump the version"
        server.submit(5).result(30)  # resamples: stale entries evicted
        stats = server.stats
        assert stats.invalidations > 0, "stale entries were not evicted"
        print(f"freshness: version {v0} -> {store.version}, "
              f"{stats.invalidations} stale entries evicted")

        recompiles = server.steady_state_recompiles
        print(f"stats: {stats.served} served in {stats.batches} batches "
              f"{dict(sorted(stats.batch_sizes.items()))}, "
              f"embedding hits/misses "
              f"{stats.embedding_hits}/{stats.embedding_misses}, "
              f"steady-state recompiles {recompiles}")
        if rep.errors or rep2.errors:
            raise SystemExit(f"load generation saw errors: "
                             f"closed={rep.errors} open={rep2.errors}")
        if recompiles != 0:
            raise SystemExit(f"serving invariant violated: {recompiles} "
                             "steady-state recompile(s) — a live request "
                             "missed the warmed bucket ladder")
    finally:
        server.close()
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

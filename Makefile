# Tier-1 verification, kernel suites, example smoke, and the perf gate.
#
#   make test          — the tier-1 command (collection must succeed even
#                        without optional test deps like hypothesis)
#   make lint          — repro-lint (tools/repro_lint): stdlib-only AST
#                        checks for determinism/purity (PUR), thread/
#                        socket/lock lifecycle (THR/SOC/LCK/BLE), jit/
#                        pallas trace safety (TRC), wire-kind and
#                        mesh-axis consistency (WIRE/MESH) and Pallas
#                        VMEM envelope sanity (PAL).  Suppress a finding
#                        with `# noqa: CODE — reason` (reason required);
#                        exits non-zero on any non-baselined finding.
#   make test-kernels  — kernel + dispatch parity suites in interpret mode
#   make ci            — what the CI test matrix runs: both of the above
#   make smoke         — end-to-end example drivers (quickstart, the
#                        flash-GAT loss/grad parity gate, and the OGBN-MAG
#                        trainer sharded over 8 forced CPU devices)
#   make smoke-multihost — 2-process jax.distributed OGBN-MAG run (4 CPU
#                        devices per process) with sampler batches over
#                        TCP; per-rank logs land in MULTIHOST_LOG_DIR
#                        (CI uploads them as artifacts)
#   make smoke-serve   — GNN inference serving driver (bucket-ladder
#                        micro-batching + caches) on 8 forced CPU devices;
#                        exits non-zero on any steady-state recompile
#   make smoke-storage — out-of-core training driver: writes a
#                        GraphDirectory, dials in mmap-backed sampler
#                        workers over TCP, and asserts loss parity with
#                        the in-memory fleet plus per-worker peak RSS
#                        below total graph bytes
#   make bench         — the benchmark sections that write BENCH_*.json
#   make check-bench   — snapshot committed baselines, re-run bench, fail
#                        on >25% us_per_call regression or gate violation;
#                        serving p50/p99 percentiles compare at
#                        --latency-tolerance 3.0 (step-function detector:
#                        tail latency across boxes is noisy, the absolute
#                        bounds live in each BENCH file's own gates)
#   make check-bench-serve — the serve section only, against its own
#                        baseline snapshot (what the CI serve job runs)
#   make check-bench-graphstore — the graphstore section only, against
#                        its own baseline snapshot (CI storage job)
#   make bench-dispatch— segment-pool dispatch benchmark only

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
BENCH_BASELINE := $(or $(TMPDIR),/tmp)/repro_bench_baseline
MULTIHOST_LOG_DIR ?= results/multihost_logs

.PHONY: test test-kernels ci lint smoke smoke-multihost smoke-serve \
    smoke-storage bench check-bench check-bench-serve \
    check-bench-graphstore bench-dispatch

test:
	$(PYTHON) -m pytest -x -q

lint:
	$(PYTHON) -m tools.repro_lint src

test-kernels:
	$(PYTHON) -m pytest -x -q tests/test_kernels.py tests/test_dispatch.py

ci: test test-kernels

smoke:
	$(PYTHON) examples/quickstart.py
	$(PYTHON) examples/gat_flash_parity.py
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/ogbn_mag_train.py --steps 3 --num-devices 8 \
	    --papers 320
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/ogbn_mag_train.py --steps 3 --num-devices 8 \
	    --model-parallel 2 --papers 320
	$(PYTHON) examples/ogbn_mag_train.py --steps 3 --num-devices 1 \
	    --papers 320
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/ogbn_mag_train.py --steps 3 --num-devices 8 \
	    --papers 320 --sampler service
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/graph_classification_train.py --steps 3 \
	    --num-devices 8 --expect-loss 1.3365
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/link_prediction_train.py --steps 3 \
	    --num-devices 8 --expect-loss 2.6875

smoke-multihost:
	$(PYTHON) examples/ogbn_mag_train.py --steps 3 --num-devices 8 \
	    --multihost 2 --papers 320 \
	    --multihost-log-dir $(MULTIHOST_LOG_DIR)

smoke-serve:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PYTHON) examples/gnn_serve.py

smoke-storage:
	$(PYTHON) examples/out_of_core_train.py

bench:
	$(PYTHON) -m benchmarks.run --quick --only dispatch
	$(PYTHON) -m benchmarks.run --quick --only layout
	$(PYTHON) -m benchmarks.run --quick --only dp_scaling
	$(PYTHON) -m benchmarks.run --quick --only mp_scaling
	$(PYTHON) -m benchmarks.run --quick --only sampler_service
	$(PYTHON) -m benchmarks.run --quick --only multihost
	$(PYTHON) -m benchmarks.run --quick --only serve
	$(PYTHON) -m benchmarks.run --quick --only graphstore

check-bench:
	rm -rf $(BENCH_BASELINE)
	mkdir -p $(BENCH_BASELINE)
	cp results/BENCH_*.json $(BENCH_BASELINE)/
	rm -f results/BENCH_*.json  # a bench that fails must not leave the
	                            # committed baseline behind as "fresh"
	$(MAKE) bench
	$(PYTHON) scripts/check_bench.py --baseline $(BENCH_BASELINE) \
	    --fresh results \
	    --require BENCH_sampler_service.json \
	    --require BENCH_dp_scaling.json \
	    --require BENCH_mp_scaling.json \
	    --require BENCH_segment_pool_dispatch.json \
	    --require BENCH_kernel_layout.json \
	    --require BENCH_multihost.json \
	    --require BENCH_serve.json \
	    --require BENCH_graphstore.json \
	    --latency-tolerance 3.0

check-bench-serve:
	rm -rf $(BENCH_BASELINE)_serve
	mkdir -p $(BENCH_BASELINE)_serve
	-cp results/BENCH_serve.json $(BENCH_BASELINE)_serve/ 2>/dev/null
	rm -f results/BENCH_serve.json
	$(PYTHON) -m benchmarks.run --quick --only serve
	$(PYTHON) scripts/check_bench.py --baseline $(BENCH_BASELINE)_serve \
	    --fresh results --require BENCH_serve.json --latency-tolerance 3.0

check-bench-graphstore:
	rm -rf $(BENCH_BASELINE)_graphstore
	mkdir -p $(BENCH_BASELINE)_graphstore
	-cp results/BENCH_graphstore.json $(BENCH_BASELINE)_graphstore/ \
	    2>/dev/null
	rm -f results/BENCH_graphstore.json
	$(PYTHON) -m benchmarks.run --quick --only graphstore
	$(PYTHON) scripts/check_bench.py \
	    --baseline $(BENCH_BASELINE)_graphstore \
	    --fresh results --require BENCH_graphstore.json \
	    --latency-tolerance 3.0

bench-dispatch:
	$(PYTHON) -m benchmarks.run --quick --only dispatch

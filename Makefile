# Tier-1 verification and kernel suites.
#
#   make test          — the tier-1 command (collection must succeed even
#                        without optional test deps like hypothesis)
#   make test-kernels  — kernel + dispatch parity suites in interpret mode
#   make ci            — what CI runs: both of the above
#   make bench-dispatch— segment-pool dispatch benchmark (BENCH_*.json)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-kernels ci bench-dispatch

test:
	$(PYTHON) -m pytest -x -q

test-kernels:
	$(PYTHON) -m pytest -x -q tests/test_kernels.py tests/test_dispatch.py

ci: test test-kernels

bench-dispatch:
	$(PYTHON) -m benchmarks.run --quick --only dispatch

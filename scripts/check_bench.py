#!/usr/bin/env python
"""Perf-regression gate: compare freshly produced results/BENCH_*.json
against the committed baselines.

    python scripts/check_bench.py --baseline <dir> --fresh results

Fails (exit 1) when any *timing metric* slowed down beyond its tolerance
relative to the same metric in the baseline file of the same name, or
when a file's own ``gates`` section is violated.  New benchmark files
(no baseline) and new metrics pass with a note — the gate protects
existing numbers, it does not freeze the schema.

Timing metrics are recognised by key family, all lower-is-better:

  * ``us_per_call``      — microseconds per call (any key containing it;
    the family inherits to numeric leaves below, so
    ``"us_per_call": {"1dev": ...}`` gates every entry).  Compared at
    ``--tolerance`` (default 25%).
  * ``p50_ms`` / ``p99_ms`` (any ``p<digits>[_digits]_ms`` percentile
    key) — serving-latency percentiles in milliseconds.  Compared at
    ``--latency-tolerance`` (default 100%): wall-clock tail latency on a
    shared box is far noisier than a tight compute kernel, so the
    baseline comparison is a step-function detector (losing a jit cache
    is 10-100x) while each benchmark's own ``gates`` carry the hard
    absolute bounds.

Throughput and other higher-is-better numbers are gated via ``gates``,
which lets a benchmark carry self-describing acceptance bounds::

    "gates": {"speedup_8dev_vs_1dev": {"min": 1.5},
              "closed_loop_cold.p99_ms": {"max": 2000}}

keyed by dotted path into the same JSON document (``min`` gates
higher-is-better metrics like QPS, ``max`` gates lower-is-better ones
like latency or recompile counts).

Only ``BENCH_*.json`` files participate.  Other artifacts under
results/ — in particular ``autotune_cache.json``, the kernel
autotuner's tuning record (see src/repro/kernels/autotune.py) — are
machine-local tuning state, not benchmark results, and are excluded by
construction of the glob; do not widen it to ``*.json``.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

# key family -> scale to microseconds (for the --min-us noise floor)
_PERCENTILE_MS = re.compile(r"p\d+(_\d+)?_ms$")


def metric_family(key: str):
    """'us' | 'ms' when `key` names a lower-is-better timing metric."""
    if "us_per_call" in key:
        return "us"
    if _PERCENTILE_MS.fullmatch(key):
        return "ms"
    return None


def collect_metrics(obj, path=(), family=None):
    """{dotted_path: (value, family)} for every numeric leaf under a
    timing-metric key (family inherits downward, so dict-valued metric
    keys gate each of their entries)."""
    out = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(collect_metrics(v, path + (str(k),),
                                       metric_family(str(k)) or family))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        if family is not None:
            out[".".join(path)] = (float(obj), family)
    return out


def lookup(obj, dotted):
    for part in dotted.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def check_file(fresh_path: Path, base_path: Path | None, tolerance: float,
               min_us: float, latency_tolerance: float):
    failures, notes = [], []
    fresh = json.loads(fresh_path.read_text())

    for dotted, spec in (fresh.get("gates") or {}).items():
        val = lookup(fresh, dotted)
        if val is None:
            failures.append(f"{fresh_path.name}: gate field {dotted!r} "
                            "missing from document")
            continue
        if "min" in spec and val < spec["min"]:
            failures.append(f"{fresh_path.name}: {dotted} = {val:.3f} "
                            f"below gate min {spec['min']}")
        if "max" in spec and val > spec["max"]:
            failures.append(f"{fresh_path.name}: {dotted} = {val:.3f} "
                            f"above gate max {spec['max']}")

    if base_path is None or not base_path.exists():
        notes.append(f"{fresh_path.name}: no committed baseline "
                     "(new benchmark) — timing comparison skipped")
        return failures, notes

    base = json.loads(base_path.read_text())
    base_metrics = collect_metrics(base)
    fresh_metrics = collect_metrics(fresh)
    to_us = {"us": 1.0, "ms": 1000.0}
    tol_for = {"us": tolerance, "ms": latency_tolerance}
    for key, (base_val, family) in sorted(base_metrics.items()):
        if key not in fresh_metrics:
            failures.append(f"{fresh_path.name}: metric {key} present in "
                            "baseline but missing from fresh results")
            continue
        fresh_val, _ = fresh_metrics[key]
        if base_val * to_us[family] < min_us:
            notes.append(f"{fresh_path.name}: {key} baseline "
                         f"{base_val:.3f}{family} below --min-us, skipped")
            continue
        tol = tol_for[family]
        ratio = fresh_val / base_val if base_val else float("inf")
        line = (f"{fresh_path.name}: {key} {base_val:.1f} -> "
                f"{fresh_val:.1f} {family} ({ratio - 1.0:+.0%})")
        if ratio > 1.0 + tol:
            failures.append(line + f" exceeds {tol:.0%} tolerance")
        else:
            notes.append(line)
    for key in sorted(set(fresh_metrics) - set(base_metrics)):
        val, family = fresh_metrics[key]
        notes.append(f"{fresh_path.name}: new metric {key} "
                     f"({val:.1f}{family}), no baseline")
    return failures, notes


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="results",
                    help="dir with freshly produced BENCH_*.json")
    ap.add_argument("--baseline", required=True,
                    help="dir with the committed baseline BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed fractional slowdown for us_per_call "
                         "metrics (default 0.25)")
    ap.add_argument("--latency-tolerance", type=float, default=1.0,
                    help="allowed fractional slowdown for p50_ms/p99_ms "
                         "latency percentiles (default 1.0: tail latency "
                         "on shared boxes is noisy — the absolute bounds "
                         "live in each file's own gates)")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore baseline metrics faster than this "
                         "(timer noise floor; ms metrics are converted)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="BENCH_x.json",
                    help="registered benchmark files that MUST be present "
                         "in --fresh (a bench section that silently "
                         "skipped/crashed fails the gate instead of "
                         "vanishing); repeatable")
    args = ap.parse_args(argv)

    fresh_dir, base_dir = Path(args.fresh), Path(args.baseline)
    fresh_files = sorted(fresh_dir.glob("BENCH_*.json"))
    if not fresh_files:
        print(f"check_bench: no BENCH_*.json under {fresh_dir}/",
              file=sys.stderr)
        return 1

    all_failures = []
    for name in args.require:
        if not (fresh_dir / name).exists():
            all_failures.append(f"{name}: registered via --require but "
                                "missing from fresh results")
            print(f"  FAIL {all_failures[-1]}")
    for f in fresh_files:
        failures, notes = check_file(f, base_dir / f.name, args.tolerance,
                                     args.min_us, args.latency_tolerance)
        for n in notes:
            print(f"  ok   {n}")
        for x in failures:
            print(f"  FAIL {x}")
        all_failures += failures
    for b in sorted(base_dir.glob("BENCH_*.json")):
        if not (fresh_dir / b.name).exists():
            all_failures.append(f"{b.name}: baseline exists but fresh run "
                                "produced no such file")
            print(f"  FAIL {all_failures[-1]}")

    if all_failures:
        print(f"check_bench: {len(all_failures)} failure(s)")
        return 1
    print(f"check_bench: {len(fresh_files)} file(s) within tolerance "
          f"of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
